//! Bench: Fig 16 (ours) — raw-speed kernels, seed-era reference vs
//! the packed register-blocked GEMM / panelled transposes /
//! nnz-balanced SpMM, on identical inputs. Every case asserts
//! bit-identity before it is timed, so a reported speedup is by
//! construction answer-preserving. GFLOP/s and speedup per row;
//! numbers land in EXPERIMENTS.md §Perf.
//!
//! `--fast` shrinks the shapes for smoke runs (kick-tires.sh);
//! `--json FILE` / `--csv FILE` additionally write machine-readable
//! copies.

use gad::bench_util::run_fig16_kernels;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast");
    let flag = |name: &str| {
        args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
    };

    let (warmup, samples) = if fast { (1, 3) } else { (1, 5) };
    let rep = run_fig16_kernels(fast, warmup, samples);

    println!("\n{}", rep.to_markdown());
    if let Some(path) = flag("--json") {
        std::fs::write(&path, rep.to_json()).expect("write --json");
        eprintln!("wrote {path}");
    }
    if let Some(path) = flag("--csv") {
        std::fs::write(&path, rep.to_csv()).expect("write --csv");
        eprintln!("wrote {path}");
    }
}
