//! Bench: Table 2 — test accuracy of the 7 methods (scaled-down
//! datasets so `cargo bench` terminates in minutes; `gad table2` runs
//! the full sizes).

use gad::baselines::{train_method, Method};
use gad::coordinator::TrainConfig;
use gad::datasets::Dataset;
use gad::metrics::MarkdownTable;

fn main() {
    let cfg = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: 64,
        lr: 0.01,
        epochs: 30,
        stop_on_converge: true,
        seed: 42,
        ..Default::default()
    };
    let mut table = MarkdownTable::new(&["Method", "Cora", "Pubmed", "Flicker", "Reddit"]);
    let datasets: Vec<(&str, Dataset)> = ["cora", "pubmed", "flickr", "reddit"]
        .iter()
        .map(|&n| (n, Dataset::by_name_scaled(n, 42, 0.125).unwrap()))
        .collect();

    for m in Method::ALL {
        let mut cells = vec![m.label().to_string()];
        for (name, ds) in &datasets {
            if m == Method::SaintEdge && (*name == "flickr" || *name == "reddit") {
                cells.push("-".into());
                continue; // paper: SAINT-Edge skipped on large datasets
            }
            let t0 = std::time::Instant::now();
            let r = train_method(ds, m, &cfg, if *name == "pubmed" { 400 } else { 150 }).unwrap();
            eprintln!(
                "{:28} {name:8} acc {:.4}  ({:.1}s)",
                m.label(),
                r.test_accuracy,
                t0.elapsed().as_secs_f64()
            );
            cells.push(format!("{:.4}", r.test_accuracy));
        }
        table.row(cells);
    }
    println!("\n== Table 2 (1/8-scale) ==\n{}", table.render());
}
