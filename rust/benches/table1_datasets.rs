//! Bench: Table 1 — dataset generation cost + printed statistics.
//! Regenerates the statistics table the paper reports, and times the
//! synthetic generators (the data substrate).

use gad::bench_util::Bencher;
use gad::datasets::{Dataset, SyntheticSpec};

fn main() {
    let mut b = Bencher::new(1, 3);
    println!("== Table 1: dataset statistics (synthetic, Table-1-shaped) ==\n");
    println!("| Dataset | Nodes | Edges | Labels | Features | Train/Val/Test |");
    println!("|---|---|---|---|---|---|");
    for spec in [
        SyntheticSpec::cora_like(),
        SyntheticSpec::pubmed_like(),
        SyntheticSpec::flickr_like(),
        SyntheticSpec::reddit_like(),
    ] {
        let ds = spec.generate(42);
        ds.validate().expect("dataset invariant");
        println!("{}", ds.stats_row());
    }
    println!("\n== generation cost ==");
    b.bench("generate cora-like (2.7k nodes)", || SyntheticSpec::cora_like().generate(1));
    b.bench("generate pubmed-like (19.7k nodes)", || SyntheticSpec::pubmed_like().generate(1));
    b.bench("generate reddit-like (11.6k nodes, 580k edges)", || {
        SyntheticSpec::reddit_like().generate(1)
    });
    let _ = Dataset::by_name("tiny", 1);
}
