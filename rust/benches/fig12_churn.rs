//! Bench: Fig 12 (ours) — serving under churn. Trains a small model,
//! stands up an Exact-halo sharded deployment, then interleaves random
//! `GraphDelta` bursts with query blocks at increasing churn rates,
//! comparing the incremental overlay path (splice in place, batched
//! compaction) against a per-delta flat-CSR rebuild.
//!
//! Output: CSV `mode,deltas_per_round,delta_mean_us,delta_p99_us,
//! deltas_per_sec,query_p50_us,query_p99_us,rows_invalidated,
//! serving_bytes,shard_rebuilds,compactions`.

use gad::coordinator::{train_gad, TrainConfig};
use gad::datasets::SyntheticSpec;
use gad::serve::{run_churn_bench, ChurnBenchConfig};

fn main() {
    let ds = SyntheticSpec::tiny().generate(42);
    let cfg = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: 48,
        lr: 0.02,
        epochs: 12,
        seed: 42,
        ..Default::default()
    };
    let report = train_gad(&ds, &cfg).expect("training run");
    let params = report.final_params.expect("trained parameters");
    eprintln!("trained: acc {:.4}; churn sweep...", report.test_accuracy);

    let bcfg = ChurnBenchConfig {
        shards: 4,
        rounds: 8,
        deltas_per_round: vec![1, 4, 16, 64],
        queries_per_round: 256,
        batch: 32,
        seed: 42,
        ..Default::default()
    };
    let rep = run_churn_bench(&ds, &params, &bcfg).expect("churn bench");
    print!("{}", rep.to_csv());
    if let Some(x) = rep.incremental_speedup() {
        eprintln!("incremental vs rebuild delta throughput at max churn: {x:.1}x");
    }
}
