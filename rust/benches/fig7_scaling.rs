//! Bench: Fig 7 — training time vs workers x layers (pubmed, scaled).
//! The paper's claim: time falls sub-linearly with workers and
//! flattens (consensus overhead).

use gad::coordinator::{train_gad, TrainConfig};
use gad::datasets::Dataset;
use gad::metrics::MarkdownTable;

fn main() {
    let ds = Dataset::by_name_scaled("pubmed", 42, 0.25).unwrap();
    let mut t = MarkdownTable::new(&["Workers", "2 Layers (s)", "3 Layers (s)", "4 Layers (s)"]);
    for workers in 1..=4usize {
        let mut cells = vec![workers.to_string()];
        for layers in 2..=4usize {
            let cfg = TrainConfig {
                partitions: 8,
                workers,
                layers,
                hidden: 64,
                lr: 0.01,
                epochs: 15,
                seed: 42,
                ..Default::default()
            };
            let r = train_gad(&ds, &cfg).unwrap();
            eprintln!("workers {workers} layers {layers}: {:.2}s", r.wall_seconds);
            cells.push(format!("{:.2}", r.wall_seconds));
        }
        t.row(cells);
    }
    println!("\n== Fig 7 (pubmed 1/4-scale) ==\n{}", t.render());
}
