//! Bench: Fig 8 — loss convergence vs partition count, augmentation
//! on/off (pubmed, scaled). The paper's claim: curves spread without
//! augmentation, collapse together with it.

use gad::coordinator::{train_gad, TrainConfig};
use gad::datasets::Dataset;

fn main() {
    let ds = Dataset::by_name_scaled("pubmed", 42, 0.125).unwrap();
    println!("augment,partitions,final_loss,loss_at_half");
    let mut spreads = Vec::new();
    for augment in [true, false] {
        let mut finals = Vec::new();
        for k in [4usize, 10, 20] {
            let cfg = TrainConfig {
                partitions: k,
                workers: 4,
                layers: 3,
                hidden: 64,
                lr: 0.01,
                epochs: 25,
                augment,
                alpha: 0.02,
                seed: 42,
                ..Default::default()
            };
            let r = train_gad(&ds, &cfg).unwrap();
            let last = r.curve.last().unwrap().loss;
            let mid = r.curve[r.curve.len() / 2].loss;
            println!("{augment},{k},{last:.4},{mid:.4}");
            finals.push(last);
        }
        let spread = finals.iter().cloned().fold(f32::MIN, f32::max)
            - finals.iter().cloned().fold(f32::MAX, f32::min);
        spreads.push((augment, spread));
    }
    for (augment, spread) in spreads {
        println!("# loss spread across partition counts (aug={augment}): {spread:.4}");
    }
}
