//! Bench: Table 3 — accuracy stability across workers x layers
//! (pubmed, scaled).

use gad::coordinator::{train_gad, TrainConfig};
use gad::datasets::Dataset;
use gad::metrics::MarkdownTable;

fn main() {
    let ds = Dataset::by_name_scaled("pubmed", 42, 0.125).unwrap();
    let mut table = MarkdownTable::new(&["Workers", "2 Layers", "3 Layers", "4 Layers"]);
    for workers in 1..=4usize {
        let mut cells = vec![format!("{workers} worker(s)")];
        for layers in 2..=4usize {
            let cfg = TrainConfig {
                partitions: 8,
                workers,
                layers,
                hidden: 64,
                lr: 0.01,
                epochs: 30,
                seed: 42,
                ..Default::default()
            };
            let r = train_gad(&ds, &cfg).unwrap();
            eprintln!("workers {workers} layers {layers}: acc {:.4} ({:.1}s)", r.test_accuracy, r.wall_seconds);
            cells.push(format!("{:.4}", r.test_accuracy));
        }
        table.row(cells);
    }
    println!("\n== Table 3 (pubmed 1/8-scale) ==\n{}", table.render());
}
