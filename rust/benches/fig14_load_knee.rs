//! Bench: Fig 14 (ours) — the open-loop latency-vs-throughput knee.
//! Trains a small model, then sweeps the offered arrival rate against
//! the serving tier: each step generates one seeded schedule (Poisson
//! arrivals, Zipfian popularity, interleaved churn) and replays it
//! under the FIFO scheduler and the SLO-aware micro-batcher on fresh
//! warmed servers, so every comparison row saw identical load. Goodput
//! (answers within SLO) holds near the offered rate below the knee and
//! collapses past it — FIFO first, the batcher later.
//!
//! Output: CSV `mode,offered_qps,achieved_qps,goodput_qps,
//! goodput_ratio,p50_us,p99_us,p999_us,mean_queue_us,mean_service_us,
//! queue_depth_mean,queue_depth_max,answered,deltas`.

use gad::coordinator::{train_gad, TrainConfig};
use gad::datasets::SyntheticSpec;
use gad::loadgen::{run_load_bench, LoadBenchConfig};

fn main() {
    let ds = SyntheticSpec::tiny().generate(42);
    let cfg = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: 48,
        lr: 0.02,
        epochs: 12,
        seed: 42,
        ..Default::default()
    };
    let report = train_gad(&ds, &cfg).expect("training run");
    let params = report.final_params.expect("trained parameters");
    eprintln!("trained: acc {:.4}; offered-rate sweep...", report.test_accuracy);

    let bcfg = LoadBenchConfig { shards: 4, seed: 42, ..Default::default() };
    let rep = run_load_bench(&ds, &params, &bcfg).expect("load bench");
    print!("{}", rep.to_csv());
    eprintln!(
        "calibrated capacity ~= {:.0} qps; fifo knee {:?} qps, slo-batch knee {:?} qps",
        rep.calibrated_qps,
        rep.knee_qps("fifo"),
        rep.knee_qps("slo-batch"),
    );
    if let Some((rate, fifo, batch)) = rep.past_knee_goodput() {
        eprintln!(
            "past the fifo knee (offered {rate:.0} qps): slo-batch goodput {batch:.0} qps vs fifo {fifo:.0} qps"
        );
    }
}
