//! Offline stub of the `xla` (PJRT) crate.
//!
//! The build container has no crates.io registry and no PJRT plugin, so
//! this path dependency mirrors the API slice `gad::runtime` and
//! `gad::backend::XlaBackend` consume. Host-side [`Literal`] packing is
//! real (so marshalling code is exercised by tests); every device entry
//! point — client construction, compilation, execution — returns an
//! "XLA unavailable" error. `BackendKind::Native` is unaffected.
//!
//! Replacing this stub with the real `xla` crate in `rust/Cargo.toml`
//! re-enables the AOT/PJRT path with no source changes.

use std::fmt;

/// Error type; the callers format it with `{:?}`.
#[derive(Clone)]
pub struct XlaError(String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

type XResult<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> XResult<T> {
    Err(XlaError(format!(
        "{what}: XLA/PJRT is unavailable in this offline build (the `xla` \
         dependency is the in-repo stub; link the real crate to enable it)"
    )))
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

/// Host-side tensor value. Packing works; device transfer does not.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    shape: Vec<i64>,
}

impl Literal {
    /// 1-D f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), shape: vec![data.len() as i64] }
    }

    /// Reshape without changing element count.
    pub fn reshape(&self, dims: &[i64]) -> XResult<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(XlaError(format!(
                "reshape: cannot view {} elements as {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), shape: dims.to_vec() })
    }

    /// Dimensions of this literal.
    pub fn shape(&self) -> &[i64] {
        &self.shape
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> XResult<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Decompose a tuple literal (device results only — stubbed).
    pub fn to_tuple(self) -> XResult<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module (stub: parsing requires the real runtime).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XResult<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation handle built from an [`HloModuleProto`].
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle (stub: construction always fails cleanly).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> XResult<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XResult<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with host inputs; result is per-device, per-output
    /// buffers in the real crate.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> XResult<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XResult<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_pack_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.shape(), &[2, 3]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 4]).is_err());
    }

    #[test]
    fn device_paths_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        let msg = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("unavailable"), "{msg}");
    }
}
