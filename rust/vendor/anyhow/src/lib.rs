//! Offline shim of the `anyhow` error crate.
//!
//! The build container has no crates.io registry, so this path
//! dependency implements exactly the subset the `gad` workspace uses:
//!
//! * [`Error`] — a context-chained error value; `{e}` prints the
//!   outermost message, `{e:#}` prints the whole chain joined by `: `
//!   (matching real anyhow's alternate formatting).
//! * [`Result<T>`] with the `E = Error` default.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! * [`Context`] for `Result` and `Option`.
//! * `From<E: std::error::Error>` so `?` lifts std errors.
//!
//! Dropping the real crate back in (same API surface) requires only a
//! one-line Cargo.toml change; no source edits.

use std::fmt;

/// A context-chained error. `chain[0]` is the outermost message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what real anyhow's
    /// `Error::context` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Outermost message only (what `{}` displays).
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// NOTE: Error deliberately does NOT implement std::error::Error —
// exactly like real anyhow — which is what makes this blanket From
// coherent alongside core's reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`; the second parameter keeps `Result<T, E>`
/// spellable for code that imports this alias unqualified.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error arm of a `Result` (or to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = anyhow!("inner {}", 7).context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn question_mark_lifts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert_eq!(format!("{e:#}"), "reading x: missing file");
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("empty").unwrap_err()), "empty");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 9 {
                bail!("nine is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).is_err());
        assert!(f(9).is_err());
    }

    #[test]
    fn anyhow_accepts_display_values() {
        let e = anyhow!(String::from("already a message"));
        assert_eq!(format!("{e}"), "already a message");
    }
}
