//! Quickstart: train GAD on a small synthetic graph in ~10 seconds.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the XLA backend (AOT Pallas/JAX artifacts) when
//! `artifacts/manifest.txt` exists, else the native backend.

use gad::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. a dataset: 400-node label-correlated SBM (Table-1 shaped)
    let dataset = SyntheticSpec::tiny().generate(42);
    println!(
        "dataset: {} nodes, {} edges, {} classes",
        dataset.num_nodes(),
        dataset.graph.num_edges(),
        dataset.num_classes
    );

    // 2. configuration: 4 subgraphs on 2 workers, 2-layer GCN
    let backend = if std::path::Path::new("artifacts/manifest.txt").exists() {
        println!("backend: xla (AOT artifacts found)");
        BackendKind::Xla
    } else {
        println!("backend: native (run `make artifacts` for the XLA path)");
        BackendKind::Native
    };
    let cfg = TrainConfig {
        partitions: 4,
        workers: 2,
        layers: 2,
        hidden: 32,
        lr: 0.02,
        epochs: 40,
        backend,
        log_every: 10,
        seed: 42,
        ..TrainConfig::default()
    };

    // 3. the full GAD pipeline: multilevel partition -> Monte-Carlo
    //    augmentation -> least-loaded subgraph loading -> synchronous
    //    training with zeta-weighted global consensus
    let report = gad::coordinator::train_gad(&dataset, &cfg)?;

    println!();
    println!("test accuracy      {:.4}", report.test_accuracy);
    println!("epochs             {}", report.epochs_run);
    println!("wall time          {:.2}s", report.wall_seconds);
    println!("edge cut           {}", report.edge_cut);
    println!("replicated nodes   {}", report.replicas_total);
    println!(
        "communication      {:.3} MB features + {:.3} MB gradients",
        report.comm.feature_mb(),
        report.comm.gradient_bytes as f64 / 1e6
    );
    Ok(())
}
