//! Serving walkthrough: train → checkpoint → serve → mutate → re-query.
//!
//! ```bash
//! cargo run --release --example serving
//! ```
//!
//! The serving tier shards the graph with the training-time partitioner
//! and gives every shard a replicated L-hop halo, so queries are
//! answered entirely shard-locally; a `GraphDelta` invalidates exactly
//! the cached embeddings within L hops of the touched region.

use gad::model::checkpoint;
use gad::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. train
    let dataset = SyntheticSpec::tiny().generate(42);
    let cfg = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: 64,
        lr: 0.02,
        epochs: 20,
        seed: 42,
        ..TrainConfig::default()
    };
    let report = gad::coordinator::train_gad(&dataset, &cfg)?;
    println!("trained: test accuracy {:.4}", report.test_accuracy);

    // 2. checkpoint to disk and reload with dimension validation
    let params = report.final_params.expect("training yields parameters");
    let path = std::env::temp_dir().join("gad_serving_example.ckpt");
    checkpoint::save(&params, &path)?;
    let params = checkpoint::load_validated(&path, dataset.feature_dim(), dataset.num_classes)?;
    println!("checkpoint reloaded from {}", path.display());

    // 3. stand up the sharded server (exact L-hop halos; the online
    //    rebalancer defends a 1.5x max/min part-size ratio)
    let mut server = Server::for_dataset(
        &dataset,
        params,
        ServeConfig { shards: 4, seed: 42, rebalance: true, ..ServeConfig::default() },
    )?;
    println!(
        "serving {} nodes over {} shards, resident {:.2} MB",
        dataset.num_nodes(),
        server.num_shards(),
        server.resident_bytes() as f64 / 1e6
    );

    // 4. query: first cold, then from the embedding cache
    let nodes: Vec<u32> = vec![0, 7, 42, 199];
    for pass in ["cold", "warm"] {
        let results = server.query_batch(&nodes)?;
        for r in &results {
            println!(
                "  [{pass}] node {:4} -> class {} (p={:.3}, shard {}, cache_hit={}, recomputed {})",
                r.node,
                r.pred,
                r.probs[r.pred as usize],
                r.shard,
                r.cache_hit,
                r.rows_recomputed
            );
        }
    }

    // 5. mutate the graph online: edge churn + a feature update —
    //    spliced through the overlay CSR in O(Δ), no global rebuild
    let delta = GraphDelta {
        added_edges: vec![(0, 42)],
        updated_features: vec![(7, vec![0.25; dataset.feature_dim()])],
        ..GraphDelta::default()
    };
    let rep = server.apply_delta(&delta)?;
    println!(
        "delta applied: version {}, {} seed nodes, {} cached rows invalidated, {:.1} KB propagated, {} shard(s) re-induced",
        rep.graph_version,
        rep.seeds,
        rep.rows_invalidated,
        rep.serving_bytes as f64 / 1e3,
        rep.shards_rebuilt,
    );

    // 6. re-query: touched nodes recompute, untouched ones still hit
    let results = server.query_batch(&nodes)?;
    for r in &results {
        println!(
            "  [post-delta] node {:4} -> class {} (v{}, cache_hit={}, recomputed {})",
            r.node, r.pred, r.graph_version, r.cache_hit, r.rows_recomputed
        );
    }

    // 7. elastic membership: grow and shrink the deployment online
    let newcomer = GraphDelta {
        added_nodes: vec![gad::serve::NewNode {
            features: vec![0.1; dataset.feature_dim()],
            edges: vec![0, 42],
        }],
        ..GraphDelta::default()
    };
    let rep = server.apply_delta(&newcomer)?;
    let new_id = (server.num_nodes() - 1) as u32;
    let answer = server.query(new_id)?;
    println!(
        "node {new_id} joined online (v{}, homed on shard {}), class {}",
        rep.graph_version, answer.shard, answer.pred
    );
    server.apply_delta(&GraphDelta { removed_nodes: vec![new_id], ..GraphDelta::default() })?;
    println!("node {new_id} retired online: query now errors = {}", server.query(new_id).is_err());

    // 8. skewed growth: every newcomer attaches next to node 0, so
    //    plurality homing would pile them all onto one shard — the
    //    rebalancer migrates boundary nodes to hold the balance
    let mut grow = GraphDelta::default();
    for i in 0..32 {
        grow.added_nodes.push(gad::serve::NewNode {
            features: vec![0.02 * (i as f32 + 1.0); dataset.feature_dim()],
            edges: vec![0],
        });
    }
    let rep = server.apply_delta(&grow)?;
    println!(
        "skewed growth: +{} nodes, rebalancer migrated {} ({} bytes); max/min part ratio {:.2}",
        rep.nodes_added,
        rep.rebalance_moves,
        rep.rebalance_bytes,
        server.imbalance_ratio()
    );

    let st = server.stats();
    println!(
        "totals: {} queries / {} micro-batches, {} cache hits, {} rows recomputed, +{} / -{} nodes, {} migrated, serving {:.2} MB + rebalance {:.2} MB",
        st.queries,
        st.micro_batches,
        st.cache_hits,
        st.rows_recomputed,
        st.nodes_added,
        st.nodes_removed,
        st.nodes_migrated,
        st.comm.serving_mb(),
        st.comm.rebalance_mb()
    );
    std::fs::remove_file(&path).ok();
    Ok(())
}
