//! Serving under load: drive the sharded server with the open-loop
//! workload generator and compare FIFO against SLO-aware micro-batch
//! scheduling on the same seeded arrival schedule.
//!
//! ```bash
//! cargo run --release --example serving_under_load
//! ```
//!
//! The generator never waits for the server — arrivals keep landing at
//! the offered rate whether or not the backlog is draining, which is
//! what exposes the queueing collapse a closed-loop bench structurally
//! cannot see. Both schedulers replay byte-identical arrivals, Zipfian
//! popularity, and interleaved graph churn.

use gad::loadgen::{generate_schedule, run_open_loop, SimOptions};
use gad::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. train a small model to serve
    let dataset = SyntheticSpec::tiny().generate(42);
    let cfg = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: 64,
        lr: 0.02,
        epochs: 20,
        seed: 42,
        ..TrainConfig::default()
    };
    let report = gad::coordinator::train_gad(&dataset, &cfg)?;
    let params = report.final_params.expect("training yields parameters");
    println!("trained: test accuracy {:.4}", report.test_accuracy);

    // 2. one seeded open-loop schedule: Poisson arrivals, Zipf-skewed
    //    query popularity, 3% of arrivals are graph deltas
    let wcfg = WorkloadConfig {
        rate_qps: 30_000.0,
        events: 3_000,
        zipf_s: 0.9,
        churn_frac: 0.03,
        seed: 42,
        ..WorkloadConfig::default()
    };
    let schedule = generate_schedule(&dataset.graph, dataset.feature_dim(), &wcfg);
    println!(
        "schedule: {} arrivals over {:.1} virtual ms at {:.0} offered qps",
        schedule.len(),
        schedule.last().map(|a| a.at_us as f64 / 1e3).unwrap_or(0.0),
        wcfg.rate_qps
    );

    // 3. replay it under both schedulers on fresh servers
    let opts = SimOptions { slo_us: 5_000, record_probs: false };
    for mode in ["fifo", "slo-batch"] {
        let scfg = ServeConfig { shards: 4, seed: 42, ..ServeConfig::default() };
        let mut server = Server::for_dataset(&dataset, params.clone(), scfg)?;
        let mut fifo = FifoScheduler::new();
        let mut batch = SloBatchScheduler::new(server.num_shards(), 16, opts.slo_us / 4);
        let sched: &mut dyn Scheduler = if mode == "fifo" { &mut fifo } else { &mut batch };
        let sim = run_open_loop(&mut server, &schedule, sched, &opts)?;

        let answered = sim.outcomes.len().max(1);
        let within = sim.outcomes.iter().filter(|o| o.within_slo).count();
        let mean_wait: f64 =
            sim.outcomes.iter().map(|o| o.queueing_us() as f64).sum::<f64>() / answered as f64;
        println!(
            "[{mode}] {} answers ({} deltas applied), {:.1}% within the {:.0} ms SLO, \
             mean wait {:.0} µs, {} flushes, queue depth max {}",
            sim.outcomes.len(),
            sim.deltas_applied,
            within as f64 / answered as f64 * 100.0,
            opts.slo_us as f64 / 1e3,
            mean_wait,
            sim.flushes,
            sim.queue_depth_max
        );
        let st = server.stats();
        println!(
            "       server saw {} queries / {} micro-batches; SLO counters: {} in / {} late",
            st.queries, st.micro_batches, st.slo_answers, st.late_answers
        );
    }
    println!("(for the full offered-rate sweep and the knee: `gad load-bench` → fig14)");
    Ok(())
}
