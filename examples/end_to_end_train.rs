//! End-to-end driver: the full three-layer stack on a real small
//! workload, proving all layers compose.
//!
//! Rust coordinator (L3) -> PJRT runtime -> AOT HLO artifacts built
//! from the JAX model (L2) wrapping the Pallas kernels (L1). Python is
//! never executed here — `make artifacts` must have run once.
//!
//! Workload: a full-scale cora-like citation graph (2 708 nodes,
//! 1 433-dim features, 7 classes) partitioned into 16 augmented
//! subgraphs on 4 workers, trained for a few hundred consensus rounds;
//! the loss curve is logged and written to results/e2e_loss_curve.csv
//! (recorded in EXPERIMENTS.md §End-to-end).
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end_train
//! ```

use gad::coordinator::{train_gad, TrainConfig};
use gad::datasets::SyntheticSpec;
use gad::prelude::BackendKind;

fn main() -> anyhow::Result<()> {
    let use_xla = std::path::Path::new("artifacts/manifest.txt").exists();
    if !use_xla {
        eprintln!("WARNING: artifacts/ missing — falling back to the native backend.");
        eprintln!("         Run `make artifacts` to exercise the full L1/L2/L3 stack.");
    }

    let dataset = SyntheticSpec::cora_like().generate(7);
    println!(
        "workload: cora-like  {} nodes / {} edges / {} classes / {}-dim features",
        dataset.num_nodes(),
        dataset.graph.num_edges(),
        dataset.num_classes,
        dataset.feature_dim()
    );

    let cfg = TrainConfig {
        partitions: 16,
        workers: 4,
        layers: 2,
        hidden: 128,
        lr: 0.01,
        epochs: 25, // 16 subgraphs / 4 workers -> 4 rounds/epoch = 100 consensus rounds
        backend: if use_xla { BackendKind::Xla } else { BackendKind::Native },
        log_every: 1,
        seed: 7,
        ..TrainConfig::default()
    };
    println!(
        "config: k={} workers={} layers={} hidden={} backend={:?}",
        cfg.partitions, cfg.workers, cfg.layers, cfg.hidden, cfg.backend
    );

    let report = train_gad(&dataset, &cfg)?;

    // loss curve -> CSV (EXPERIMENTS.md §End-to-end)
    let mut csv = String::from("epoch,seconds,loss,test_accuracy\n");
    for p in &report.curve {
        csv.push_str(&format!("{},{:.3},{:.6},{:.4}\n", p.epoch, p.seconds, p.loss, p.accuracy));
    }
    gad::metrics::write_result_file("results/e2e_loss_curve.csv", &csv)?;

    println!();
    println!("=== end-to-end report ===");
    println!("backend            {:?}", cfg.backend);
    println!("test accuracy      {:.4}", report.test_accuracy);
    println!("val accuracy       {:.4}", report.val_accuracy);
    println!("consensus rounds   {}", report.epochs_run * 4);
    println!("wall time          {:.1}s", report.wall_seconds);
    println!("time-to-converge   {:.1}s", report.time_to_converge);
    println!("edge cut           {}", report.edge_cut);
    println!("replicas           {}", report.replicas_total);
    println!("feature comm       {:.3} MB", report.comm.feature_mb());
    println!("gradient comm      {:.3} MB", report.comm.gradient_bytes as f64 / 1e6);
    println!("memory/worker      {:.1} MB", report.memory_mb_per_worker());
    println!("loss curve         results/e2e_loss_curve.csv");

    let first = report.curve.first().map(|p| p.loss).unwrap_or(0.0);
    let last = report.curve.last().map(|p| p.loss).unwrap_or(0.0);
    anyhow::ensure!(last < first, "loss did not decrease ({first} -> {last})");
    println!("loss {first:.4} -> {last:.4}  OK");
    Ok(())
}
