//! Baseline comparison: the seven methods of Table 2 on one dataset,
//! printed as a markdown table with accuracy + convergence time
//! (a one-dataset slice of `gad table2` / `gad fig6`).
//!
//! ```bash
//! cargo run --release --example baseline_comparison -- [dataset]
//! ```
//! `dataset` defaults to `tiny`; use cora/pubmed/flickr/reddit for the
//! full-size runs (minutes each).

use gad::baselines::{train_method, Method};
use gad::coordinator::TrainConfig;
use gad::datasets::Dataset;
use gad::metrics::MarkdownTable;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "tiny".to_string());
    let dataset = Dataset::by_name(&name, 42)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))?;
    println!(
        "dataset {name}: {} nodes / {} edges",
        dataset.num_nodes(),
        dataset.graph.num_edges()
    );

    let cfg = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: if name == "tiny" { 32 } else { 128 },
        lr: 0.01,
        epochs: if name == "tiny" { 40 } else { 80 },
        stop_on_converge: true,
        seed: 42,
        ..TrainConfig::default()
    };
    let batch = if name == "pubmed" { 1500 } else { 300 };

    let mut table = MarkdownTable::new(&[
        "Method",
        "Test acc",
        "Converge (s)",
        "Epochs",
        "Feature comm (MB)",
    ]);
    let mut gad_time = None;
    for m in Method::ALL {
        let r = train_method(&dataset, m, &cfg, batch)?;
        eprintln!("{:30} acc {:.4}  t {:.1}s", m.label(), r.test_accuracy, r.time_to_converge);
        if m == Method::Gad {
            gad_time = Some(r.time_to_converge);
        }
        table.row(vec![
            m.label().to_string(),
            format!("{:.4}", r.test_accuracy),
            format!("{:.2}", r.time_to_converge),
            r.epochs_run.to_string(),
            format!("{:.3}", r.comm.feature_mb()),
        ]);
    }
    println!("\n{}", table.render());
    if let Some(t) = gad_time {
        println!("(GAD convergence time: {t:.2}s — compare per-row for the Fig. 6 speedups)");
    }
    Ok(())
}
