//! Augmentation analysis: what GAD-Partition actually replicates.
//!
//! Walks one dataset through partition -> Monte-Carlo importance ->
//! depth-first selection and prints, per part: boundary size, candidate
//! count, walks used by the Eq.-4 estimator, replica budget/actual, and
//! the feature-traffic saving the replicas buy (the Table-4 mechanism,
//! inspectable).
//!
//! ```bash
//! cargo run --release --example augmentation_analysis -- [dataset] [k] [alpha]
//! ```

use gad::augment::{augment_part, AugmentConfig};
use gad::comm::weighted_feature_traffic_per_epoch;
use gad::datasets::Dataset;
use gad::graph::{boundary_nodes, candidate_replication_nodes};
use gad::metrics::MarkdownTable;
use gad::partition::{partition, PartitionConfig};
use gad::variance::{zeta, ZetaConfig};

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let name = args.next().unwrap_or_else(|| "cora".to_string());
    let k: usize = args.next().map(|s| s.parse()).transpose()?.unwrap_or(8);
    let alpha: f64 = args.next().map(|s| s.parse()).transpose()?.unwrap_or(0.01);
    let layers = 2usize;

    let dataset = Dataset::by_name(&name, 42)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))?;
    println!(
        "dataset {name}: {} nodes / {} edges; k={k}, alpha={alpha}, l={layers}\n",
        dataset.num_nodes(),
        dataset.graph.num_edges()
    );

    let part = partition(
        &dataset.graph,
        &PartitionConfig { k, seed: 42, ..Default::default() },
    );
    println!(
        "partition: edge cut {} ({:.1}% of edges), balance {:.3}\n",
        part.edge_cut,
        100.0 * part.edge_cut as f64 / dataset.graph.num_edges() as f64,
        part.balance
    );

    let cfg = AugmentConfig { alpha, walk_length: layers, seed: 42, ..Default::default() };
    let mut table = MarkdownTable::new(&[
        "part", "nodes", "boundary", "candidates", "MC walks", "replicas", "zeta",
        "traffic w/o aug (KB)", "traffic w/ aug (KB)", "saved",
    ]);
    let (mut total_before, mut total_after) = (0u64, 0u64);
    for p in 0..k as u32 {
        let aug = augment_part(&dataset.graph, &part.assignment, p, &cfg);
        let boundary = boundary_nodes(&dataset.graph, &part.assignment, p);
        let cands = candidate_replication_nodes(&dataset.graph, &part.assignment, p, layers);
        let before = weighted_feature_traffic_per_epoch(
            &aug.candidate_importance, &[], boundary.len(), dataset.feature_dim(),
        );
        let after = weighted_feature_traffic_per_epoch(
            &aug.candidate_importance, &aug.replicas, boundary.len(), dataset.feature_dim(),
        );
        total_before += before;
        total_after += after;
        let z = zeta(&aug.sub.csr, None, &ZetaConfig::default());
        table.row(vec![
            p.to_string(),
            aug.base_len().to_string(),
            boundary.len().to_string(),
            cands.len().to_string(),
            aug.walks_used.to_string(),
            aug.replicas.len().to_string(),
            format!("{z:.3}"),
            format!("{:.1}", before as f64 / 1e3),
            format!("{:.1}", after as f64 / 1e3),
            format!("{:.0}%", 100.0 * (1.0 - after as f64 / before.max(1) as f64)),
        ]);
    }
    println!("{}", table.render());
    println!(
        "total feature traffic per epoch: {:.2} MB -> {:.2} MB ({:.0}% saved)",
        total_before as f64 / 1e6,
        total_after as f64 / 1e6,
        100.0 * (1.0 - total_after as f64 / total_before.max(1) as f64)
    );
    Ok(())
}
