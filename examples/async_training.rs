//! Asynchronous training: bounded-staleness consensus with an injected
//! straggler, compared against the synchronous baseline.
//!
//! ```bash
//! cargo run --release --example async_training
//! ```
//!
//! The async engine lets healthy workers push gradient updates without
//! waiting for the 150ms straggler; contributions are discounted by
//! `zeta * lambda^staleness` and anything older than the staleness
//! bound is dropped while the laggard re-pulls a fresh replica.

use gad::coordinator::{Fault, FaultPlan};
use gad::prelude::*;

fn main() -> anyhow::Result<()> {
    let dataset = SyntheticSpec::tiny().generate(42);
    println!(
        "dataset: {} nodes, {} edges, {} classes",
        dataset.num_nodes(),
        dataset.graph.num_edges(),
        dataset.num_classes
    );

    let base = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: 64,
        lr: 0.02,
        epochs: 12,
        seed: 42,
        ..TrainConfig::default()
    };
    // worker 0 sleeps 150ms before every step from epoch 0 on
    let faults = FaultPlan {
        faults: vec![Fault::Straggle { worker: 0, epoch: 0, millis: 150 }],
    };

    // 1. synchronous rounds: every epoch waits for the straggler
    let mut sync_cfg = base.clone();
    sync_cfg.consensus = ConsensusMode::Weighted;
    sync_cfg.faults = faults.clone();
    let sync = gad::coordinator::train_gad(&dataset, &sync_cfg)?;

    // 2. bounded-staleness async: quorum-1 updates, staleness bound 3
    let mut async_cfg = base.clone();
    async_cfg.consensus = ConsensusMode::Async(AsyncConfig {
        staleness: 3,
        quorum: 1,
        lambda: 0.5,
        zeta_weighted: true,
    });
    async_cfg.faults = faults;
    let asy = gad::coordinator::train_gad(&dataset, &async_cfg)?;

    println!("\n== straggler (150ms) comparison ==");
    println!(
        "sync : acc {:.4}  wall {:.2}s  grad {:.2} MB",
        sync.test_accuracy,
        sync.wall_seconds,
        sync.comm.gradient_bytes as f64 / 1e6
    );
    println!(
        "async: acc {:.4}  wall {:.2}s  grad {:.2} MB  resyncs {} ({:.2} MB)  max staleness {}",
        asy.test_accuracy,
        asy.wall_seconds,
        asy.comm.gradient_bytes as f64 / 1e6,
        asy.resyncs,
        asy.comm.resync_mb(),
        asy.max_staleness_applied
    );
    println!(
        "speedup: {:.2}x wall-clock",
        sync.wall_seconds / asy.wall_seconds.max(1e-9)
    );
    Ok(())
}
