#!/usr/bin/env bash
# Small-N smoke of the serving figure family (fig11–16): build the CLI,
# run serve-bench + load-bench (with a trace) + profile + kernel-bench
# in --fast mode into out/, and assert the artifacts landed non-empty
# and the JSON artifacts parse. This is the "does the whole pipeline
# still produce numbers" check — correctness lives in `cargo test`.
set -euo pipefail

cd "$(dirname "$0")/.."
OUT="${1:-out}"

# static contract audit first: it needs only python3, so it still runs
# (and still gates) in containers that have no rust toolchain at all
echo "== kick-tires: static contract audit =="
mkdir -p "$OUT"
python3 scripts/analysis/audit.py --json "$OUT/static_audit.json"
python3 -m json.tool "$OUT/static_audit.json" >/dev/null
echo "ok: $OUT/static_audit.json parses as JSON"

echo "== kick-tires: building release CLI =="
cargo build --release --manifest-path rust/Cargo.toml

GAD=rust/target/release/gad
if [[ ! -x "$GAD" ]]; then
    echo "error: $GAD not built" >&2
    exit 1
fi

echo "== kick-tires: fig11-13 (serve-bench, fast, tiny, 4-wide serve pool) =="
"$GAD" serve-bench --dataset tiny --fast --serve-threads 4 --out-dir "$OUT"

echo "== kick-tires: fig14 (load-bench, fast, tiny, 4-wide serve pool, traced) =="
"$GAD" load-bench --dataset tiny --fast --load-events 200 --rate-steps 3 \
    --serve-threads 4 --out-dir "$OUT" --trace "$OUT/trace_load.json"

echo "== kick-tires: fig15 (profile, fast, tiny) =="
"$GAD" profile --dataset tiny --fast --out-dir "$OUT"

echo "== kick-tires: fig16 (kernel-bench, fast shapes) =="
"$GAD" kernel-bench --fast --out-dir "$OUT"

echo "== kick-tires: checking artifacts =="
status=0
for f in \
    fig11_serving_latency.md fig11_serving_latency.csv fig11_serving_latency.json \
    fig12_churn.md fig12_churn.csv fig12_churn.json \
    fig13_rebalance.md fig13_rebalance.csv fig13_rebalance.json \
    fig14_load_knee.md fig14_load_knee.csv fig14_load_knee.json \
    fig15_profile.md fig15_profile.csv fig15_profile.json \
    fig16_kernels.md fig16_kernels.csv fig16_kernels.json \
    trace_load.json; do
    if [[ ! -s "$OUT/$f" ]]; then
        echo "MISSING or empty: $OUT/$f" >&2
        status=1
    else
        echo "ok: $OUT/$f ($(wc -l < "$OUT/$f") lines)"
    fi
done

# the Chrome trace must be loadable JSON (Perfetto / chrome://tracing)
if command -v python3 >/dev/null 2>&1; then
    for f in trace_load.json fig15_profile.json fig16_kernels.json; do
        if python3 -m json.tool "$OUT/$f" >/dev/null; then
            echo "ok: $OUT/$f parses as JSON"
        else
            echo "INVALID JSON: $OUT/$f" >&2
            status=1
        fi
    done
else
    echo "warn: python3 not found, skipping JSON parse check"
fi

# machine-readable perf trajectory: stable BENCH_* names at the repo
# root of $OUT, one json per tracked figure
cp "$OUT/fig11_serving_latency.json" "$OUT/BENCH_fig11.json"
cp "$OUT/fig12_churn.json" "$OUT/BENCH_fig12.json"
cp "$OUT/fig13_rebalance.json" "$OUT/BENCH_fig13.json"
cp "$OUT/fig14_load_knee.json" "$OUT/BENCH_fig14.json"
cp "$OUT/fig15_profile.json" "$OUT/BENCH_fig15.json"
cp "$OUT/fig16_kernels.json" "$OUT/BENCH_fig16.json"
for f in BENCH_fig11.json BENCH_fig12.json BENCH_fig13.json BENCH_fig14.json BENCH_fig15.json BENCH_fig16.json static_audit.json; do
    if [[ ! -s "$OUT/$f" ]]; then
        echo "MISSING or empty: $OUT/$f" >&2
        status=1
    else
        echo "ok: $OUT/$f"
    fi
done

if [[ $status -ne 0 ]]; then
    echo "kick-tires FAILED" >&2
    exit $status
fi
echo "kick-tires passed: fig11-16 artifacts (+BENCH_*.json, static_audit.json, trace) present in $OUT/"
