#!/usr/bin/env bash
# Small-N smoke of the serving figure family (fig11–14): build the CLI,
# run serve-bench + load-bench in --fast mode into out/, and assert the
# artifacts landed non-empty. This is the "does the whole pipeline
# still produce numbers" check — correctness lives in `cargo test`.
set -euo pipefail

cd "$(dirname "$0")/.."
OUT="${1:-out}"

echo "== kick-tires: building release CLI =="
cargo build --release --manifest-path rust/Cargo.toml

GAD=rust/target/release/gad
if [[ ! -x "$GAD" ]]; then
    echo "error: $GAD not built" >&2
    exit 1
fi

echo "== kick-tires: fig11-13 (serve-bench, fast, tiny, 4-wide serve pool) =="
"$GAD" serve-bench --dataset tiny --fast --serve-threads 4 --out-dir "$OUT"

echo "== kick-tires: fig14 (load-bench, fast, tiny, 4-wide serve pool) =="
"$GAD" load-bench --dataset tiny --fast --load-events 200 --rate-steps 3 \
    --serve-threads 4 --out-dir "$OUT"

echo "== kick-tires: checking artifacts =="
status=0
for f in \
    fig11_serving_latency.md fig11_serving_latency.csv fig11_serving_latency.json \
    fig12_churn.md fig12_churn.csv \
    fig13_rebalance.md fig13_rebalance.csv \
    fig14_load_knee.md fig14_load_knee.csv fig14_load_knee.json; do
    if [[ ! -s "$OUT/$f" ]]; then
        echo "MISSING or empty: $OUT/$f" >&2
        status=1
    else
        echo "ok: $OUT/$f ($(wc -l < "$OUT/$f") lines)"
    fi
done

# machine-readable perf trajectory: stable BENCH_* names at the repo
# root of $OUT, one json per tracked figure
cp "$OUT/fig11_serving_latency.json" "$OUT/BENCH_fig11.json"
cp "$OUT/fig14_load_knee.json" "$OUT/BENCH_fig14.json"
for f in BENCH_fig11.json BENCH_fig14.json; do
    if [[ ! -s "$OUT/$f" ]]; then
        echo "MISSING or empty: $OUT/$f" >&2
        status=1
    else
        echo "ok: $OUT/$f"
    fi
done

if [[ $status -ne 0 ]]; then
    echo "kick-tires FAILED" >&2
    exit $status
fi
echo "kick-tires passed: fig11-14 artifacts (+BENCH_*.json) present in $OUT/"
