"""Self-tests for the static contract checker.

Every rule family gets a fires / doesn't-fire fixture pair, so a
refactor of the analyzer that silently stops detecting a violation
class fails here instead of shipping a green-but-blind audit. Pure
stdlib; run with::

    python3 -m unittest discover scripts/analysis
"""

from __future__ import annotations

import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import audit  # noqa: E402
import rules_determinism  # noqa: E402
import rules_exports  # noqa: E402
import rules_hygiene  # noqa: E402
import rules_observability  # noqa: E402
import rules_threading  # noqa: E402
from rustlex import SourceFile, make_key, slugify, strip_comments_and_strings  # noqa: E402


class Ctx:
    def __init__(self, files, readme_text=""):
        self.root = "/nonexistent"
        self.files = files
        self.readme_text = readme_text


def src(relpath, text, kind="src"):
    return SourceFile.from_text(relpath, text, kind)


def rules_of(findings):
    return sorted(f.rule for f in findings)


# A README whose inventory matches the spans the fixtures emit.
INVENTORY = (
    "## Spans\n"
    "<!-- span-inventory:begin -->\n"
    "| `train.epoch` | wall | trainer |\n"
    "<!-- span-inventory:end -->\n"
)


class LexerTests(unittest.TestCase):
    def test_comments_and_strings_are_stripped(self):
        code, pure = strip_comments_and_strings(
            'let x = "Instant::now"; // Instant::now\n/* Instant::now */ let y = 1;\n'
        )
        self.assertNotIn("Instant::now", pure)
        self.assertIn('"Instant::now"', code)  # code keeps strings
        self.assertNotIn("// Instant::now", code)  # ...but not comments

    def test_raw_strings_and_lifetimes(self):
        code, pure = strip_comments_and_strings(
            'let r = r#"un"balanced // not a comment"#;\nfn f<\'a>(x: &\'a str) {}\n'
        )
        self.assertNotIn("not a comment", pure)
        self.assertIn("'a", pure)  # lifetime survives char-literal logic

    def test_cfg_test_region_is_masked(self):
        sf = src(
            "rust/src/a.rs",
            "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n",
        )
        self.assertFalse(sf.in_test(0))
        self.assertTrue(sf.in_test(3))
        self.assertFalse(sf.in_test(5))

    def test_make_key_is_line_content_based(self):
        a = make_key("D-TIME", "rust/src/a.rs", "  let t0 = Instant::now();  ")
        b = make_key("D-TIME", "rust/src/a.rs", "let t0 = Instant::now();")
        self.assertEqual(a, b)
        self.assertTrue(a.startswith("D-TIME:rust/src/a.rs:"))
        self.assertLessEqual(len(slugify("x" * 500)), 60)


class DeterminismTests(unittest.TestCase):
    def test_time_banned_fires_in_banned_zone(self):
        ctx = Ctx([src("rust/src/graph/x.rs", "fn f() { let t = Instant::now(); }\n")])
        fs = rules_determinism.run(ctx)
        self.assertEqual(rules_of(fs), ["D-TIME-BANNED"])
        self.assertFalse(fs[0].suppressable)

    def test_time_elsewhere_is_allowlistable_warn(self):
        ctx = Ctx([src("rust/src/serve/x.rs", "fn f() { let t = Instant::now(); }\n")])
        fs = rules_determinism.run(ctx)
        self.assertEqual(rules_of(fs), ["D-TIME"])
        self.assertTrue(fs[0].suppressable)

    def test_duration_arithmetic_outside_banned_zone_is_clean(self):
        ctx = Ctx([src("rust/src/serve/x.rs", "use std::time::Duration;\nfn f(d: Duration) {}\n")])
        self.assertEqual(rules_determinism.run(ctx), [])

    def test_clock_in_cfg_test_is_exempt(self):
        ctx = Ctx(
            [src("rust/src/graph/x.rs", "#[cfg(test)]\nmod t {\n fn f() { Instant::now(); }\n}\n")]
        )
        self.assertEqual(rules_determinism.run(ctx), [])

    def test_entropy_fires_outside_rng(self):
        bad = Ctx([src("rust/src/augment/x.rs", "fn f() { let r = rand::thread_rng(); }\n")])
        ok = Ctx([src("rust/src/rng.rs", "fn f() { let r = rand::thread_rng(); }\n")])
        self.assertIn("D-ENTROPY", rules_of(rules_determinism.run(bad)))
        self.assertEqual(rules_determinism.run(ok), [])

    def test_hash_iter_fires_without_sort(self):
        text = (
            "use std::collections::HashMap;\n"
            "fn f(m: &HashMap<u32, u32>) -> Vec<u32> {\n"
            "    let mut out = Vec::new();\n"
            "    for (k, _) in m { out.push(*k); }\n"
            "    out\n}\n"
        )
        # the `m: &HashMap<...>` param form is the binding detector here
        ctx = Ctx([src("rust/src/serve/x.rs", text)])
        self.assertEqual(rules_of(rules_determinism.run(ctx)), ["D-HASH-ITER"])

    def test_hash_iter_redeemed_by_sort_within_window(self):
        text = (
            "fn f() {\n"
            "    let m: HashMap<u32, u32> = HashMap::new();\n"
            "    let mut ks: Vec<u32> = m.keys().copied().collect();\n"
            "    ks.sort_unstable();\n"
            "}\n"
        )
        ctx = Ctx([src("rust/src/serve/x.rs", text)])
        self.assertEqual(rules_determinism.run(ctx), [])

    def test_hash_iter_order_insensitive_terminal_is_clean(self):
        text = "fn f() {\n    let m: HashMap<u32, u32> = HashMap::new();\n    let n = m.keys().count();\n}\n"
        ctx = Ctx([src("rust/src/serve/x.rs", text)])
        self.assertEqual(rules_determinism.run(ctx), [])

    def test_local_vec_shadowing_a_hash_field_name_is_clean(self):
        # regression: a struct field `edges: HashSet<..>` must not make a
        # *local* Vec named `edges` in another fn fire the rule
        text = (
            "struct S {\n    edges: HashSet<u64>,\n}\n"
            "fn g(nn: &N) {\n"
            "    for &v in nn.edges.iter().rev() { use_it(v); }\n"
            "}\n"
        )
        # nn.edges here is a Vec field of N, not S's HashSet — only
        # `self.edges` / `x.edges` on an S would be genuinely unordered,
        # but the rule cannot see types; it must at least not fire on a
        # *bare* local of the same name:
        text2 = (
            "struct S {\n    edges: HashSet<u64>,\n}\n"
            "fn g(edges: &Vec<u64>) {\n"
            "    for &v in edges { use_it(v); }\n"
            "}\n"
        )
        ctx = Ctx([src("rust/src/serve/x.rs", text2)])
        self.assertEqual(rules_determinism.run(ctx), [])
        # ...while the prefixed receiver still fires:
        ctx = Ctx([src("rust/src/serve/y.rs", text)])
        self.assertEqual(rules_of(rules_determinism.run(ctx)), ["D-HASH-ITER"])


class ThreadingTests(unittest.TestCase):
    def test_spawn_fires_in_src_not_in_tests(self):
        bad = Ctx([src("rust/src/a.rs", "fn f() { std::thread::spawn(|| {}); }\n")])
        self.assertEqual(rules_of(rules_threading.run(bad)), ["T-SPAWN"])
        tst = Ctx(
            [src("rust/src/a.rs", "#[cfg(test)]\nmod t {\n fn f() { std::thread::spawn(|| {}); }\n}\n")]
        )
        self.assertEqual(rules_threading.run(tst), [])
        scoped = Ctx([src("rust/src/a.rs", "fn f() { std::thread::scope(|s| {}); }\n")])
        self.assertEqual(rules_threading.run(scoped), [])

    def test_static_needs_a_nearby_comment(self):
        bad = Ctx([src("rust/src/a.rs", "static COUNTER: AtomicU64 = AtomicU64::new(0);\n")])
        self.assertEqual(rules_of(rules_threading.run(bad)), ["T-SHARED-COMMENT"])
        ok = Ctx(
            [src(
                "rust/src/a.rs",
                "// read only after the scope joins; relaxed is safe\n"
                "static COUNTER: AtomicU64 = AtomicU64::new(0);\n",
            )]
        )
        self.assertEqual(rules_threading.run(ok), [])

    def test_intra_lease_cross_check(self):
        bad = Ctx([src("rust/src/a.rs", "fn f(n: usize) { crate::tensor::set_intra_threads(n); }\n")])
        self.assertEqual(rules_of(rules_threading.run(bad)), ["T-INTRA-LEASE"])
        ok = Ctx(
            [src(
                "rust/src/a.rs",
                "fn f(n: usize) {\n"
                "    let _lease = crate::threads::reserve(n);\n"
                "    crate::tensor::set_intra_threads(n);\n"
                "}\n",
            )]
        )
        self.assertEqual(rules_threading.run(ok), [])
        one = Ctx([src("rust/src/a.rs", "fn f() { crate::tensor::set_intra_threads(1); }\n")])
        self.assertEqual(rules_threading.run(one), [])


class ObservabilityTests(unittest.TestCase):
    def test_undocumented_span_fires(self):
        ctx = Ctx(
            [src("rust/src/a.rs", 'fn f() { let _s = crate::span!("serve.mystery"); }\n')],
            readme_text=INVENTORY,
        )
        self.assertIn("O-SPAN-INVENTORY", rules_of(rules_observability.run(ctx)))

    def test_stale_inventory_row_fires(self):
        ctx = Ctx([src("rust/src/a.rs", "fn f() {}\n")], readme_text=INVENTORY)
        self.assertIn("O-SPAN-STALE", rules_of(rules_observability.run(ctx)))

    def test_matching_inventory_is_clean(self):
        ctx = Ctx(
            [src("rust/src/a.rs", 'fn f() { let _s = crate::span!("train.epoch"); }\n')],
            readme_text=INVENTORY,
        )
        self.assertEqual(rules_observability.run(ctx), [])

    def test_enter_under_parent_captured_inside_scope_fires(self):
        text = (
            "fn f() {\n"
            "    std::thread::scope(|s| {\n"
            "        let wid = outer.id();\n"
            '        let _g = SpanGuard::enter_under("train.epoch", Some(wid), &[]);\n'
            "    });\n"
            "}\n"
        )
        ctx = Ctx([src("rust/src/a.rs", text)], readme_text=INVENTORY)
        self.assertIn("O-ENTER-UNDER", rules_of(rules_observability.run(ctx)))

    def test_enter_under_parent_captured_before_scope_is_clean(self):
        text = (
            "fn f() {\n"
            "    let wid = outer.id();\n"
            "    std::thread::scope(|s| {\n"
            '        let _g = SpanGuard::enter_under("train.epoch", Some(wid), &[]);\n'
            "    });\n"
            "}\n"
        )
        ctx = Ctx([src("rust/src/a.rs", text)], readme_text=INVENTORY)
        self.assertEqual(rules_observability.run(ctx), [])

    def test_reference_twin_missing_pin_test_fires(self):
        lib = src(
            "rust/src/a.rs",
            "pub fn gemm_reference() {}\n"
            'pub fn gemm() { let _s = crate::span!("train.epoch"); }\n',
        )
        ctx = Ctx([lib], readme_text=INVENTORY)
        self.assertIn("O-REFERENCE-TWIN", rules_of(rules_observability.run(ctx)))
        pin = src(
            "rust/tests/pin.rs",
            "fn pin() { assert_eq!(gad::a::gemm_reference(), gad::a::gemm()); }\n",
            kind="test",
        )
        ctx = Ctx([lib, pin], readme_text=INVENTORY)
        self.assertEqual(rules_observability.run(ctx), [])

    def test_reference_without_optimized_twin_fires(self):
        lib = src("rust/src/a.rs", "pub fn gemm_reference() {}\n")
        pin = src("rust/tests/pin.rs", "fn pin() { gad::a::gemm_reference(); }\n", kind="test")
        ctx = Ctx([lib, pin], readme_text=INVENTORY)
        self.assertIn("O-REFERENCE-TWIN", rules_of(rules_observability.run(ctx)))


LIB_RS = (
    "pub mod tensor;\n"
    "mod internal;\n"
    "pub mod prelude {\n"
    "    pub use crate::tensor::Tensor;\n"
    "}\n"
)
TENSOR_RS = "pub struct Tensor;\npub fn gemm() {}\npub(crate) fn secret() {}\n"


def exports_ctx(test_text):
    return Ctx(
        [
            src("rust/src/lib.rs", LIB_RS),
            src("rust/src/tensor.rs", TENSOR_RS),
            src("rust/tests/t.rs", test_text, kind="test"),
        ]
    )


class ExportsTests(unittest.TestCase):
    def test_valid_imports_resolve(self):
        ctx = exports_ctx(
            "use gad::tensor::{Tensor, gemm};\nuse gad::prelude::*;\n"
            "fn f() { let t: gad::tensor::Tensor = gad::prelude::Tensor; }\n"
        )
        self.assertEqual(rules_exports.run(ctx), [])

    def test_nonexistent_item_fires(self):
        ctx = exports_ctx("use gad::tensor::NoSuchThing;\n")
        self.assertEqual(rules_of(rules_exports.run(ctx)), ["X-UNRESOLVED"])

    def test_private_module_fires(self):
        ctx = exports_ctx("use gad::internal;\n")
        self.assertEqual(rules_of(rules_exports.run(ctx)), ["X-UNRESOLVED"])

    def test_pub_crate_item_is_invisible_to_integration_tests(self):
        ctx = exports_ctx("use gad::tensor::secret;\n")
        self.assertEqual(rules_of(rules_exports.run(ctx)), ["X-UNRESOLVED"])

    def test_reexport_chain_resolves(self):
        ctx = exports_ctx("use gad::prelude::Tensor;\n")
        self.assertEqual(rules_exports.run(ctx), [])


class HygieneTests(unittest.TestCase):
    def test_unwrap_fires_in_lib_not_cli_or_tests(self):
        bad = Ctx([src("rust/src/a.rs", "fn f() { x.unwrap(); }\n")])
        self.assertEqual(rules_of(rules_hygiene.run(bad)), ["H-UNWRAP"])
        cli = Ctx([src("rust/src/cli/a.rs", "fn f() { x.unwrap(); }\n")])
        self.assertEqual(rules_hygiene.run(cli), [])
        tst = Ctx([src("rust/src/a.rs", "#[cfg(test)]\nmod t {\n fn f() { x.unwrap(); }\n}\n")])
        self.assertEqual(rules_hygiene.run(tst), [])

    def test_each_hygiene_pattern_fires(self):
        text = (
            "fn a() { x.expect(\"boom\"); }\n"
            "fn b() { panic!(\"no\"); }\n"
            "fn c() { println!(\"out\"); }\n"
        )
        ctx = Ctx([src("rust/src/a.rs", text)])
        self.assertEqual(rules_of(rules_hygiene.run(ctx)), ["H-EXPECT", "H-PANIC", "H-PRINT"])


class AllowlistTests(unittest.TestCase):
    def _tmp(self, content):
        f = tempfile.NamedTemporaryFile("w", suffix=".txt", delete=False)
        self.addCleanup(os.unlink, f.name)
        f.write(content)
        f.close()
        return f.name

    def _finding(self, rule="D-TIME", relpath="rust/src/a.rs", line_text="let t = Instant::now();"):
        ctx = Ctx([src(relpath, f"fn f() {{ {line_text} }}\n", kind="src")])
        fs = rules_determinism.run(ctx)
        self.assertEqual(len(fs), 1)
        return fs[0]

    def test_exact_key_suppresses(self):
        f = self._finding()
        path = self._tmp(f"{f.key}  timing only, never feeds answers\n")
        entries, malformed = audit.parse_allowlist(path)
        self.assertEqual(malformed, [])
        out = audit.apply_allowlist([f], entries, "allowlist.txt")
        self.assertTrue(out[0].allowlisted)
        self.assertEqual(len(out), 1)  # no ALLOWLIST-UNUSED

    def test_file_level_key_suppresses(self):
        f = self._finding()
        path = self._tmp("D-TIME:rust/src/a.rs  whole file is bench timing\n")
        entries, _ = audit.parse_allowlist(path)
        out = audit.apply_allowlist([f], entries, "allowlist.txt")
        self.assertTrue(out[0].allowlisted)

    def test_stale_entry_is_flagged(self):
        path = self._tmp("D-TIME:rust/src/gone.rs:let-t-Instant-now  obsolete\n")
        entries, _ = audit.parse_allowlist(path)
        out = audit.apply_allowlist([], entries, "allowlist.txt")
        self.assertEqual(rules_of(out), ["ALLOWLIST-UNUSED"])
        self.assertFalse(out[0].suppressable)

    def test_malformed_line_is_flagged(self):
        path = self._tmp("justawordwithnokey\n")
        _, malformed = audit.parse_allowlist(path)
        self.assertEqual(rules_of(malformed), ["ALLOWLIST-MALFORMED"])

    def test_non_suppressable_findings_ignore_the_allowlist(self):
        ctx = Ctx([src("rust/src/graph/x.rs", "fn f() { let t = Instant::now(); }\n")])
        f = rules_determinism.run(ctx)[0]
        self.assertEqual(f.rule, "D-TIME-BANNED")
        path = self._tmp(f"{f.key}  nice try\n")
        entries, _ = audit.parse_allowlist(path)
        out = audit.apply_allowlist([f], entries, "allowlist.txt")
        self.assertFalse(out[0].allowlisted)


class EndToEndTests(unittest.TestCase):
    def test_real_tree_is_green(self):
        """The merged tree must audit clean — same check CI runs."""
        root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        if not os.path.isdir(os.path.join(root, "rust", "src")):
            self.skipTest("not running inside the repo")
        rc = audit.main(["--root", root])
        self.assertEqual(rc, 0, "audit must exit 0 on the merged tree")


if __name__ == "__main__":
    unittest.main()
