"""Lightweight lexical model of a Rust source file.

This is NOT a Rust parser. It is the smallest amount of lexical
machinery the contract rules need to avoid lying: comment and string
stripping (so a rule never fires on prose), `#[cfg(test)]` region
detection (so test-only code is exempt from library hygiene), and
brace-depth tracking (so module-level items are distinguishable from
methods inside `impl` blocks). Everything is line-oriented; every view
of the file has exactly as many lines as the raw source, so findings
can always report real line numbers.

Three parallel views of each file:

* ``raw``   — the file as written (rules that look for the *presence*
  of a comment, e.g. the static/unsafe justification rule, read this).
* ``code``  — comments blanked, string literals kept (rules that read
  string contents, e.g. span-name extraction, read this).
* ``pure``  — comments blanked AND string contents blanked (rules that
  match code tokens, e.g. ``Instant::now`` or ``.unwrap()``, read this
  so a quoted example in a string can never fire a rule).

Zero dependencies beyond the Python 3 stdlib, by design: this harness
must run in authoring containers that have python3 and nothing else.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field


def strip_comments_and_strings(text: str):
    """Return ``(code, pure)`` — same length/line structure as ``text``.

    ``code`` blanks comments (line, nested block, doc) to spaces;
    ``pure`` additionally blanks the interiors of string/char literals
    (quotes are kept so the token shape stays visible). Handles nested
    ``/* */``, escapes inside strings, raw strings ``r#"..."#``, and
    the char-literal vs lifetime ambiguity of ``'``.
    """
    n = len(text)
    code = list(text)
    pure = list(text)
    i = 0
    NORMAL, LINE_COMMENT, BLOCK_COMMENT, STRING, RAW_STRING, CHAR = range(6)
    state = NORMAL
    block_depth = 0
    raw_hashes = 0

    def blank(buf, j):
        if buf[j] not in ("\n", "\r"):
            buf[j] = " "

    while i < n:
        c = text[i]
        nxt = text[i + 1] if i + 1 < n else ""
        if state == NORMAL:
            if c == "/" and nxt == "/":
                state = LINE_COMMENT
                blank(code, i)
                blank(pure, i)
            elif c == "/" and nxt == "*":
                state = BLOCK_COMMENT
                block_depth = 1
                blank(code, i)
                blank(pure, i)
            elif c == '"':
                # raw string? look back for r / br and hashes
                state = STRING
            elif c == "r" and (nxt == '"' or nxt == "#"):
                # r"..." or r#"..."# (also br"...")
                j = i + 1
                hashes = 0
                while j < n and text[j] == "#":
                    hashes += 1
                    j += 1
                if j < n and text[j] == '"':
                    state = RAW_STRING
                    raw_hashes = hashes
                    i = j  # keep the r and hashes; interior blanking starts past the quote
            elif c == "'":
                # char literal vs lifetime: a char literal closes with a
                # quote within a few chars ('x', '\n', '\u{1F600}')
                m = re.match(r"'(\\.[^']*|\\u\{[0-9a-fA-F]+\}|[^'\\])'", text[i:])
                if m:
                    end = i + m.end() - 1
                    k = i + 1
                    while k < end:
                        blank(pure, k)
                        k += 1
                    i = end
                # else: lifetime — fall through, nothing to blank
            i += 1
        elif state == LINE_COMMENT:
            if c == "\n":
                state = NORMAL
            else:
                blank(code, i)
                blank(pure, i)
            i += 1
        elif state == BLOCK_COMMENT:
            if c == "/" and nxt == "*":
                block_depth += 1
                blank(code, i)
                blank(pure, i)
                blank(code, i + 1)
                blank(pure, i + 1)
                i += 2
                continue
            if c == "*" and nxt == "/":
                block_depth -= 1
                blank(code, i)
                blank(pure, i)
                blank(code, i + 1)
                blank(pure, i + 1)
                i += 2
                if block_depth == 0:
                    state = NORMAL
                continue
            blank(code, i)
            blank(pure, i)
            i += 1
        elif state == STRING:
            if c == "\\":
                blank(pure, i)
                if i + 1 < n:
                    blank(pure, i + 1)
                i += 2
                continue
            if c == '"':  # closing quote (escapes were consumed above)
                state = NORMAL
                i += 1
                continue
            blank(pure, i)
            i += 1
        elif state == RAW_STRING:
            if c == '"':
                # close only on " followed by raw_hashes #s
                j = i + 1
                h = 0
                while j < n and text[j] == "#" and h < raw_hashes:
                    h += 1
                    j += 1
                if h == raw_hashes:
                    state = NORMAL
                    i = j
                    continue
            blank(pure, i)
            i += 1
        else:  # CHAR — unused (handled inline)
            i += 1
    return "".join(code), "".join(pure)


def _find_matching_brace(lines, start_line, start_col):
    """Line index of the ``}`` matching the first ``{`` at/after
    ``(start_line, start_col)`` in a list of pure lines; None if
    unbalanced."""
    depth = 0
    seen_open = False
    for li in range(start_line, len(lines)):
        col0 = start_col if li == start_line else 0
        for col in range(col0, len(lines[li])):
            ch = lines[li][col]
            if ch == "{":
                depth += 1
                seen_open = True
            elif ch == "}":
                depth -= 1
                if seen_open and depth == 0:
                    return li
    return None


@dataclass
class SourceFile:
    """One Rust file plus its stripped views and test-region mask."""

    relpath: str  # repo-relative, forward slashes
    kind: str  # "src" | "test" | "bench" | "example"
    raw: list = field(default_factory=list)
    code: list = field(default_factory=list)
    pure: list = field(default_factory=list)
    test_mask: list = field(default_factory=list)  # True = inside #[cfg(test)]

    @classmethod
    def from_text(cls, relpath: str, text: str, kind: str = "src") -> "SourceFile":
        code, pure = strip_comments_and_strings(text)
        sf = cls(
            relpath=relpath.replace("\\", "/"),
            kind=kind,
            raw=text.splitlines(),
            code=code.splitlines(),
            pure=pure.splitlines(),
        )
        # splitlines() on trailing-newline text drops nothing we need,
        # but the three views must agree on line count
        m = max(len(sf.raw), len(sf.code), len(sf.pure))
        for view in (sf.raw, sf.code, sf.pure):
            while len(view) < m:
                view.append("")
        sf.test_mask = sf._compute_test_mask()
        return sf

    @classmethod
    def from_path(cls, path, relpath: str, kind: str = "src") -> "SourceFile":
        with open(path, encoding="utf-8") as f:
            return cls.from_text(relpath, f.read(), kind)

    def _compute_test_mask(self):
        mask = [False] * len(self.pure)
        i = 0
        attr = re.compile(r"#\[\s*cfg\s*\(\s*test\s*\)\s*\]")
        while i < len(self.pure):
            if attr.search(self.pure[i]):
                # find the opening brace of the annotated item, then its close
                j = i
                col = 0
                while j < len(self.pure):
                    col = self.pure[j].find("{")
                    if col >= 0:
                        break
                    # a cfg(test) on a braceless item (use/fn decl ending in ;)
                    if ";" in self.pure[j] and j > i:
                        break
                    j += 1
                if j < len(self.pure) and col >= 0:
                    end = _find_matching_brace(self.pure, j, col)
                    end = end if end is not None else len(self.pure) - 1
                    for k in range(i, end + 1):
                        mask[k] = True
                    i = end + 1
                    continue
                else:
                    mask[i] = True
            i += 1
        return mask

    def in_test(self, line_idx: int) -> bool:
        """True if 0-based ``line_idx`` sits inside a #[cfg(test)] region."""
        return 0 <= line_idx < len(self.test_mask) and self.test_mask[line_idx]

    def code_text(self) -> str:
        return "\n".join(self.code)

    def pure_text(self) -> str:
        return "\n".join(self.pure)


def slugify(line: str, max_len: int = 60) -> str:
    """Stable allowlist key fragment for one source line: collapse
    everything non-alphanumeric to '-', truncate. Whitespace and
    line-number churn do not change it; editing the line does."""
    s = re.sub(r"[^A-Za-z0-9_]+", "-", line.strip()).strip("-")
    return s[:max_len] if s else "empty"


@dataclass
class Finding:
    """One rule violation. ``key`` is the exact allowlist key; a
    file-granular ``RULE:path`` entry also suppresses it (except for
    rules marked non-suppressable by the driver)."""

    rule: str
    severity: str  # "error" | "warn"
    relpath: str
    line: int  # 1-based; 0 = whole-file / cross-file finding
    message: str
    key: str = ""
    allowlisted: bool = False
    suppressable: bool = True

    def __post_init__(self):
        if not self.key:
            self.key = f"{self.rule}:{self.relpath}"

    @property
    def file_key(self) -> str:
        return f"{self.rule}:{self.relpath}"


def make_key(rule: str, relpath: str, line_text: str) -> str:
    return f"{rule}:{relpath}:{slugify(line_text)}"
