#!/usr/bin/env python3
"""Static contract checker for the gad repo.

Mechanizes the line-by-line audit every toolchain-free session since
PR 5 has repeated by hand: determinism (D), threading (T),
observability (O), export-surface (X), and hygiene (H) rules over
``rust/src``, ``rust/tests``, ``rust/benches``, and ``examples``.
Zero dependencies beyond the Python 3 stdlib — it must run in
authoring containers that have python3 and nothing else.

Exit status: 0 iff every finding is covered by
``scripts/analysis/allowlist.txt`` (and no allowlist entry is stale).
Every exemption is therefore explicit, justified, and diffable.

Usage::

    python3 scripts/analysis/audit.py               # human-readable text
    python3 scripts/analysis/audit.py --json out/static_audit.json
    python3 scripts/analysis/audit.py --list-rules
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from rustlex import Finding, SourceFile  # noqa: E402

import rules_determinism  # noqa: E402
import rules_exports  # noqa: E402
import rules_hygiene  # noqa: E402
import rules_observability  # noqa: E402
import rules_threading  # noqa: E402

RULE_MODULES = [
    rules_determinism,
    rules_threading,
    rules_observability,
    rules_exports,
    rules_hygiene,
]

RULE_DOCS = {
    "D-TIME-BANNED": "clock reads in graph/, tensor/, augment/, loadgen/generator.rs (never allowlistable)",
    "D-TIME": "clock reads elsewhere in rust/src need a wall-clock-only justification",
    "D-HASH-ITER": "HashMap/HashSet iteration with no sort nearby and no order-insensitive terminal",
    "D-ENTROPY": "ambient entropy (thread_rng/RandomState/rand::…) outside rng.rs",
    "T-SPAWN": "std::thread::spawn in library code (scoped threads + threads.rs leases only)",
    "T-SHARED-COMMENT": "static/Atomic/unsafe site without a nearby justification comment",
    "T-INTRA-LEASE": "set_intra_threads(non-1) in a file that never touches the thread budget",
    "O-SPAN-INVENTORY": "span emitted in code but missing from README's span inventory (never allowlistable)",
    "O-SPAN-STALE": "span listed in README's inventory but emitted nowhere (never allowlistable)",
    "O-ENTER-UNDER": "cross-thread span parent not captured before its thread::scope",
    "O-REFERENCE-TWIN": "*_reference oracle without an optimized twin + a test pinning both",
    "X-UNRESOLVED": "use/inline gad::… path in tests/benches/examples that resolves to no pub item",
    "H-UNWRAP": ".unwrap() in library code",
    "H-EXPECT": ".expect(…) in library code",
    "H-PANIC": "panic!/todo!/unimplemented! in library code",
    "H-PRINT": "println!/dbg! in library code",
    "ALLOWLIST-UNUSED": "allowlist entry that suppresses nothing (stale — remove it)",
    "ALLOWLIST-MALFORMED": "allowlist line without a key + justification",
}


class Ctx:
    def __init__(self, root, files, readme_text):
        self.root = root
        self.files = files
        self.readme_text = readme_text


def classify(relpath):
    if relpath.startswith("rust/src/"):
        return "src"
    if relpath.startswith("rust/tests/"):
        return "test"
    if relpath.startswith("rust/benches/"):
        return "bench"
    if relpath.startswith("examples/"):
        return "example"
    return None


def load_ctx(root):
    files = []
    scan_dirs = ["rust/src", "rust/tests", "rust/benches", "examples"]
    for d in scan_dirs:
        top = os.path.join(root, d)
        if not os.path.isdir(top):
            continue
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames.sort()
            if "vendor" in dirpath.replace("\\", "/").split("/"):
                continue
            for fn in sorted(filenames):
                if not fn.endswith(".rs"):
                    continue
                full = os.path.join(dirpath, fn)
                rel = os.path.relpath(full, root).replace("\\", "/")
                kind = classify(rel)
                if kind is None:
                    continue
                files.append(SourceFile.from_path(full, rel, kind))
    readme = ""
    readme_path = os.path.join(root, "README.md")
    if os.path.exists(readme_path):
        with open(readme_path, encoding="utf-8") as f:
            readme = f.read()
    return Ctx(root, files, readme)


def parse_allowlist(path):
    """Lines: ``KEY  justification…``; '#' comments and blanks skipped.
    Returns (entries: dict key->justification, findings for malformed
    lines)."""
    entries = {}
    findings = []
    if not os.path.exists(path):
        return entries, findings
    rel = os.path.basename(path)
    with open(path, encoding="utf-8") as f:
        for ln, raw in enumerate(f, 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split(None, 1)
            if len(parts) != 2 or ":" not in parts[0]:
                findings.append(
                    Finding(
                        rule="ALLOWLIST-MALFORMED",
                        severity="error",
                        relpath=f"scripts/analysis/{rel}",
                        line=ln,
                        message=(
                            "allowlist line needs `RULE:path[:slug]` followed by a "
                            f"justification: `{line[:80]}`"
                        ),
                        key=f"ALLOWLIST-MALFORMED:{rel}:{ln}",
                        suppressable=False,
                    )
                )
                continue
            entries[parts[0]] = parts[1]
    return entries, findings


def apply_allowlist(findings, entries, allowlist_rel):
    used = set()
    for f in findings:
        if not f.suppressable:
            continue
        if f.key in entries:
            f.allowlisted = True
            used.add(f.key)
        elif f.file_key in entries:
            f.allowlisted = True
            used.add(f.file_key)
    out = list(findings)
    for key in entries:
        if key not in used:
            out.append(
                Finding(
                    rule="ALLOWLIST-UNUSED",
                    severity="error",
                    relpath=allowlist_rel,
                    line=0,
                    message=(
                        f"allowlist entry `{key}` suppresses nothing — the "
                        "violation it excused is gone (or the key drifted); "
                        "remove or update the entry"
                    ),
                    key=f"ALLOWLIST-UNUSED:{key}",
                    suppressable=False,
                )
            )
    return out


def render_text(findings, n_files):
    active = [f for f in findings if not f.allowlisted]
    suppressed = [f for f in findings if f.allowlisted]
    lines = []
    by_rule = {}
    for f in active:
        by_rule.setdefault(f.rule, []).append(f)
    for rule in sorted(by_rule):
        lines.append(f"-- {rule}: {RULE_DOCS.get(rule, '')}")
        for f in sorted(by_rule[rule], key=lambda f: (f.relpath, f.line)):
            loc = f"{f.relpath}:{f.line}" if f.line else f.relpath
            lines.append(f"  {f.severity.upper():5} {loc}")
            lines.append(f"        {f.message}")
            if f.suppressable:
                lines.append(f"        allowlist key: {f.key}")
        lines.append("")
    lines.append(
        f"audit: {n_files} files scanned, {len(findings)} findings "
        f"({len(suppressed)} allowlisted, {len(active)} active)"
    )
    if active:
        lines.append("FAIL: unallowlisted findings — fix them or add justified allowlist entries")
    else:
        lines.append("OK: zero unallowlisted findings")
    return "\n".join(lines)


def to_json(findings, n_files):
    active = [f for f in findings if not f.allowlisted]
    return {
        "files_scanned": n_files,
        "findings": [
            {
                "rule": f.rule,
                "severity": f.severity,
                "file": f.relpath,
                "line": f.line,
                "message": f.message,
                "allowlist_key": f.key if f.suppressable else None,
                "allowlisted": f.allowlisted,
            }
            for f in sorted(findings, key=lambda f: (f.rule, f.relpath, f.line))
        ],
        "summary": {
            "total": len(findings),
            "active": len(active),
            "allowlisted": len(findings) - len(active),
            "ok": not active,
        },
    }


def main(argv=None):
    here = os.path.dirname(os.path.abspath(__file__))
    default_root = os.path.dirname(os.path.dirname(here))
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--root", default=default_root, help="repo root (default: two dirs up)")
    p.add_argument("--json", metavar="PATH", help="also write machine-readable findings here")
    p.add_argument(
        "--allowlist",
        default=os.path.join(here, "allowlist.txt"),
        help="suppression file (default: scripts/analysis/allowlist.txt)",
    )
    p.add_argument("--list-rules", action="store_true", help="print the rule catalogue and exit")
    args = p.parse_args(argv)

    if args.list_rules:
        for rule in sorted(RULE_DOCS):
            print(f"{rule:18} {RULE_DOCS[rule]}")
        return 0

    ctx = load_ctx(args.root)
    findings = []
    for mod in RULE_MODULES:
        findings.extend(mod.run(ctx))
    entries, malformed = parse_allowlist(args.allowlist)
    findings.extend(malformed)
    allowlist_rel = os.path.relpath(args.allowlist, args.root).replace("\\", "/")
    findings = apply_allowlist(findings, entries, allowlist_rel)

    print(render_text(findings, len(ctx.files)))
    if args.json:
        out_dir = os.path.dirname(args.json)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(to_json(findings, len(ctx.files)), f, indent=2)
            f.write("\n")
        print(f"json: {args.json}")
    return 0 if not [f for f in findings if not f.allowlisted] else 1


if __name__ == "__main__":
    sys.exit(main())
