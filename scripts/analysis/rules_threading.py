"""Rule family T — threading.

The crate's threading model (README "Threading model") has three fork
points, one shared budget (``threads.rs``), and a determinism contract
that only holds because every parallel path merges in a fixed order.
Three rules keep new code inside that model:

* ``T-SPAWN`` (error, allowlistable): ``std::thread::spawn`` in
  library code. Free-running threads escape both the scoped-borrow
  discipline and the thread budget; the two sanctioned long-lived
  spawns (trainer worker threads, joined via handles) carry allowlist
  entries explaining their lifetime.
* ``T-SHARED-COMMENT`` (warn, allowlistable): a module-level
  ``static`` item, an ``Atomic*`` declaration, or an ``unsafe`` block
  with no comment on the same line or the three lines above. Shared
  mutable state is only safe here by *argument* (see threads.rs,
  obs/trace.rs) — the rule makes the argument's presence checkable.
  Consecutive static items form one group; one comment covers it.
* ``T-INTRA-LEASE`` (error, allowlistable): a call to
  ``set_intra_threads(n)`` with non-literal-1 ``n`` in a file that
  never touches ``threads::reserve``/``ThreadLease``. Pinning 1 is
  always safe (a worker renouncing parallelism); sizing to anything
  else must visibly participate in the budget, or say where its lease
  lives.
"""

from __future__ import annotations

import re

from rustlex import Finding, make_key

SPAWN = re.compile(r"(?:std\s*::\s*)?thread\s*::\s*spawn\b")
SCOPED = re.compile(r"\b\w+\s*\.\s*spawn\s*\(")  # scope.spawn(...) / s.spawn(...)
STATIC_ITEM = re.compile(r"^\s*(?:pub(?:\([^)]*\))?\s+)?static\s+[A-Z_][A-Z0-9_]*\s*:")
ATOMIC_DECL = re.compile(r"\bAtomic(?:Bool|Usize|Isize|U8|U16|U32|U64|I8|I16|I32|I64)\b")
UNSAFE = re.compile(r"\bunsafe\b")
INTRA = re.compile(r"\bset_intra_threads\s*\(\s*([^)]*?)\s*\)")
LEASE = re.compile(r"threads\s*::\s*reserve\b|\bThreadLease\b")


def _has_nearby_comment(sf, i) -> bool:
    """A comment on the line itself or within the 3 lines above."""
    lo = max(0, i - 3)
    for j in range(lo, i + 1):
        raw = sf.raw[j]
        if "//" in raw or "/*" in raw or raw.lstrip().startswith("*"):
            return True
    return False


def run(ctx):
    findings = []
    for sf in ctx.files:
        if sf.kind != "src":
            continue
        findings.extend(_check_spawn(sf))
        findings.extend(_check_shared_comments(sf))
        findings.extend(_check_intra_lease(sf))
    return findings


def _check_spawn(sf):
    out = []
    for i, line in enumerate(sf.pure):
        if sf.in_test(i):
            continue
        m = SPAWN.search(line)
        if not m:
            continue
        out.append(
            Finding(
                rule="T-SPAWN",
                severity="error",
                relpath=sf.relpath,
                line=i + 1,
                message=(
                    "std::thread::spawn in library code — use std::thread::scope "
                    "workers sized through threads::reserve; a long-lived pool "
                    "needs an allowlist entry stating who joins it"
                ),
                key=make_key("T-SPAWN", sf.relpath, sf.raw[i]),
            )
        )
    return out


def _check_shared_comments(sf):
    out = []
    for i, line in enumerate(sf.pure):
        if sf.in_test(i):
            continue
        is_static = bool(STATIC_ITEM.match(line))
        is_unsafe = bool(UNSAFE.search(line))
        is_atomic_decl = bool(ATOMIC_DECL.search(line)) and (
            is_static or re.search(r"^\s*(?:pub(?:\([^)]*\))?\s+)?\w+\s*:\s*", line)
        )
        if not (is_static or is_unsafe or is_atomic_decl):
            continue
        # a contiguous run of statics shares one justification comment:
        # only the head of the run is checked
        if is_static and i > 0 and STATIC_ITEM.match(sf.pure[i - 1]):
            continue
        if _has_nearby_comment(sf, i):
            continue
        what = "unsafe block" if is_unsafe and not is_static else (
            "static item" if is_static else "Atomic field"
        )
        out.append(
            Finding(
                rule="T-SHARED-COMMENT",
                severity="warn",
                relpath=sf.relpath,
                line=i + 1,
                message=(
                    f"{what} with no ordering/justification comment nearby: "
                    f"`{sf.raw[i].strip()[:80]}` — shared state is safe here only "
                    "by argument; write the argument next to the site"
                ),
                key=make_key("T-SHARED-COMMENT", sf.relpath, sf.raw[i]),
            )
        )
    return out


def _check_intra_lease(sf):
    out = []
    if sf.relpath == "rust/src/tensor/ops.rs":
        return out  # the definition site
    body = sf.pure_text()
    has_lease = bool(LEASE.search(body))
    for i, line in enumerate(sf.pure):
        if sf.in_test(i):
            continue
        m = INTRA.search(line)
        if not m:
            continue
        arg = m.group(1).strip()
        if arg == "1":
            continue  # renouncing parallelism is always budget-safe
        if has_lease:
            continue
        out.append(
            Finding(
                rule="T-INTRA-LEASE",
                severity="error",
                relpath=sf.relpath,
                line=i + 1,
                message=(
                    f"set_intra_threads({arg}) in a file with no threads::reserve/"
                    "ThreadLease — size GEMM parallelism through the process "
                    "budget, or allowlist stating which file holds the lease"
                ),
                key=make_key("T-INTRA-LEASE", sf.relpath, sf.raw[i]),
            )
        )
    return out
