"""Rule family H — library-code hygiene.

``unwrap``/``expect``/``panic!``/``todo!``/``unimplemented!`` turn
recoverable errors into aborts of a serving process; ``dbg!`` and
``println!`` pollute stdout, which the CLI reserves for reports. All
five are fine in tests, benches, examples, and the CLI itself — the
rule covers library code only, and every surviving site needs an
allowlist entry arguing the invariant that makes it unreachable (or
the lock-poisoning policy that makes it deliberate).

* ``H-UNWRAP`` (warn): ``.unwrap()``
* ``H-EXPECT`` (warn): ``.expect(``
* ``H-PANIC``  (warn): ``panic!(`` / ``todo!(`` / ``unimplemented!(``
* ``H-PRINT``  (warn): ``println!(`` / ``dbg!(``
"""

from __future__ import annotations

import re

from rustlex import Finding, make_key

# CLI + bench-harness code is human-facing by design
EXEMPT_PREFIXES = (
    "rust/src/cli/",
    "rust/src/main.rs",
    "rust/src/bench_util.rs",
)

PATTERNS = [
    ("H-UNWRAP", re.compile(r"\.unwrap\s*\(\s*\)")),
    ("H-EXPECT", re.compile(r"\.expect\s*\(")),
    ("H-PANIC", re.compile(r"\b(?:panic|todo|unimplemented)!\s*[\(\[{]")),
    ("H-PRINT", re.compile(r"\b(?:println|dbg)!\s*[\(\[{]")),
]

WHAT = {
    "H-UNWRAP": "`.unwrap()` in library code",
    "H-EXPECT": "`.expect(…)` in library code",
    "H-PANIC": "panic-family macro in library code",
    "H-PRINT": "stdout/debug print in library code",
}


def run(ctx):
    findings = []
    for sf in ctx.files:
        if sf.kind != "src":
            continue
        if any(sf.relpath.startswith(p) for p in EXEMPT_PREFIXES):
            continue
        for i, line in enumerate(sf.pure):
            if sf.in_test(i):
                continue
            # debug_assert!/assert! with a panic message are assertions,
            # not control flow; the panic-family rule should not fire on
            # the word inside another macro name
            for rule, pat in PATTERNS:
                if pat.search(line):
                    findings.append(
                        Finding(
                            rule=rule,
                            severity="warn",
                            relpath=sf.relpath,
                            line=i + 1,
                            message=(
                                f"{WHAT[rule]}: `{sf.raw[i].strip()[:80]}` — return "
                                "a Result, or allowlist with the invariant that "
                                "makes this unreachable"
                            ),
                            key=make_key(rule, sf.relpath, sf.raw[i]),
                        )
                    )
    return findings
