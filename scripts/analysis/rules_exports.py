"""Rule family X — export surface.

Integration tests, benches, and examples link ``gad`` as an external
crate, so every ``use gad::…`` path and every inline ``gad::…``
expression they contain must resolve against items the library
actually declares ``pub`` (``pub(crate)`` is invisible to them). This
is exactly the class of cross-module wiring break PRs 5–9 hunted by
hand after every refactor: a renamed struct, a moved module, a
re-export dropped from the prelude.

The resolver builds a module tree from ``rust/src`` (file modules via
``pub mod x;``, inline modules via ``pub mod x { … }``), collects
module-level ``pub`` items (brace-depth tracking keeps ``impl``
methods and struct fields out), follows ``pub use`` re-export chains
(including globs), and registers ``#[macro_export]`` macros at the
crate root. Then:

* ``X-UNRESOLVED`` (error): a ``use gad::…`` leaf or an inline
  ``gad::…`` path whose module chain or leaf item does not resolve.
  Segments *after* the first non-module item (enum variants,
  associated fns) are intentionally not checked — that would need a
  type checker, and the wiring breaks live in the module chain.
"""

from __future__ import annotations

import re

from rustlex import Finding

CRATE = "gad"

ITEM = re.compile(
    r"^\s*pub(?:\((?P<vis>[^)]*)\))?\s+"
    r"(?:unsafe\s+|async\s+|const\s+(?=fn)|extern\s+\"[^\"]*\"\s+)*"
    r"(?P<kw>fn|struct|enum|trait|type|const|static|union)\s+"
    r"(?P<name>[A-Za-z_][A-Za-z0-9_]*)"
)
MOD_DECL = re.compile(
    r"^\s*pub(?:\((?P<vis>[^)]*)\))?\s+mod\s+(?P<name>[A-Za-z_][A-Za-z0-9_]*)\s*(?P<body>[;{])"
)
PUB_USE = re.compile(r"^\s*pub(?:\((?P<vis>[^)]*)\))?\s+use\s+(?P<path>[^;]+);", re.S)
MACRO_EXPORT = re.compile(r"#\[\s*macro_export\s*\]")
MACRO_RULES = re.compile(r"macro_rules!\s*([A-Za-z_][A-Za-z0-9_]*)")


class Module:
    def __init__(self, path):
        self.path = path  # tuple of segments, () = crate root
        self.items = set()  # externally-visible (plain pub) item names
        self.crate_items = set()  # pub(crate)/pub(super) — internal only
        self.submodules = {}  # name -> Module
        self.reexports = []  # (exported_name_or_None_for_glob, src_segments)


def split_use_tree(path_expr):
    """Expand a use tree into flat segment lists.

    ``a::b::{c, d::e, f as g, *}`` ->
    ``[[a,b,c], [a,b,d,e], [a,b,f] (as g), [a,b,*]]``.
    Returns list of (segments, alias_or_None).
    """
    path_expr = re.sub(r"\s+", " ", path_expr.strip())

    def parse(expr):
        expr = expr.strip()
        # top-level brace group?
        brace = expr.find("{")
        if brace >= 0 and expr.endswith("}"):
            prefix = [s for s in expr[:brace].strip().rstrip(":").split("::") if s]
            inner = expr[brace + 1 : -1]
            parts, depth, cur = [], 0, ""
            for ch in inner:
                if ch == "{":
                    depth += 1
                elif ch == "}":
                    depth -= 1
                if ch == "," and depth == 0:
                    parts.append(cur)
                    cur = ""
                else:
                    cur += ch
            if cur.strip():
                parts.append(cur)
            out = []
            for p in parts:
                for segs, alias in parse(p):
                    out.append((prefix + segs, alias))
            return out
        alias = None
        m = re.search(r"\bas\s+([A-Za-z_][A-Za-z0-9_]*)\s*$", expr)
        if m:
            alias = m.group(1)
            expr = expr[: m.start()].strip()
        segs = [s for s in expr.split("::") if s]
        return [(segs, alias)] if segs else []

    return parse(path_expr)


def _collect_statements(lines):
    """Join multi-line `use`/`pub use` statements; yield
    (start_line_idx, joined_text) for every line, with joined text only
    differing for use statements."""
    out = []
    i = 0
    while i < len(lines):
        line = lines[i]
        if re.match(r"^\s*(pub(\([^)]*\))?\s+)?use\b", line) and ";" not in line:
            j = i
            buf = line
            while j + 1 < len(lines) and ";" not in buf:
                j += 1
                buf += " " + lines[j].strip()
            out.append((i, buf))
            i = j + 1
            continue
        out.append((i, line))
        i += 1
    return out


def build_module_tree(ctx):
    """Parse rust/src into a Module tree rooted at the crate."""
    files_by_rel = {sf.relpath: sf for sf in ctx.files if sf.kind == "src"}
    root = Module(())

    def module_file(segments):
        base = "rust/src/" + "/".join(segments)
        for cand in (base + ".rs", base + "/mod.rs"):
            if cand in files_by_rel:
                return files_by_rel[cand]
        if not segments:
            return files_by_rel.get("rust/src/lib.rs")
        return None

    def brace_depths(sf):
        """Per-line depth at line start, from the pure view."""
        depths = []
        d = 0
        for line in sf.pure:
            depths.append(d)
            d += line.count("{") - line.count("}")
        return depths

    def parse_module(mod, sf, line_range=None, base_depth=0):
        depths = brace_depths(sf)
        lo, hi = (0, len(sf.pure)) if line_range is None else line_range
        stmts = _collect_statements(sf.pure[lo:hi])
        pending_macro_export = False
        for off, text in stmts:
            i = lo + off
            if sf.in_test(i):
                continue
            if depths[i] != base_depth:
                # still scan for macro_export at any depth? no — macros
                # are module-level in this crate
                continue
            first = text if "\n" not in text else text.split("\n")[0]
            if MACRO_EXPORT.search(sf.pure[i]):
                pending_macro_export = True
                continue
            mm = MACRO_RULES.search(first)
            if mm:
                if pending_macro_export:
                    root.items.add(mm.group(1))
                pending_macro_export = False
                continue
            m = MOD_DECL.match(first)
            if m:
                name = m.group("name")
                child = Module(mod.path + (name,))
                if m.group("vis"):
                    # pub(crate) mod: invisible externally; still record
                    # so internal chains resolve, but as crate-only
                    mod.crate_items.add(name)
                else:
                    mod.items.add(name)
                mod.submodules[name] = child
                if m.group("body") == ";":
                    msf = module_file(child.path)
                    if msf is not None:
                        parse_module(child, msf)
                else:
                    # inline module: parse its brace range at depth+1
                    from rustlex import _find_matching_brace

                    col = sf.pure[i].find("{")
                    end = _find_matching_brace(sf.pure, i, col)
                    end = end if end is not None else len(sf.pure) - 1
                    parse_module(child, sf, (i + 1, end), depths[i] + 1)
                continue
            m = PUB_USE.match(text)
            if m:
                for segs, alias in split_use_tree(m.group("path")):
                    leaf = alias or (segs[-1] if segs else None)
                    if leaf == "*":
                        mod.reexports.append((None, segs))
                    elif leaf:
                        if alias:
                            mod.reexports.append((alias, segs))
                        else:
                            mod.reexports.append((leaf, segs))
                continue
            m = ITEM.match(first)
            if m:
                if m.group("vis"):
                    mod.crate_items.add(m.group("name"))
                else:
                    mod.items.add(m.group("name"))

    lib = module_file(())
    if lib is not None:
        parse_module(root, lib)
    return root


class Resolver:
    def __init__(self, root):
        self.root = root

    def _normalize(self, mod, segs):
        """Resolve leading crate/self/super/gad to a module + tail."""
        segs = list(segs)
        cur = mod
        while segs:
            head = segs[0]
            if head in ("crate", CRATE):
                cur = self.root
                segs.pop(0)
            elif head == "self":
                segs.pop(0)
            elif head == "super":
                cur = self._module_at(cur.path[:-1])
                segs.pop(0)
            else:
                break
        return cur, segs

    def _module_at(self, path):
        cur = self.root
        for s in path:
            cur = cur.submodules.get(s)
            if cur is None:
                return self.root
        return cur

    def resolve_module(self, mod, segs):
        """Descend while segments name submodules; return (module,
        remaining_segments) or (None, segs) if a middle segment is
        neither submodule nor resolvable."""
        cur, segs = self._normalize(mod, segs)
        i = 0
        while i < len(segs):
            nxt = cur.submodules.get(segs[i])
            if nxt is None:
                break
            cur = nxt
            i += 1
        return cur, segs[i:]

    def has_item(self, mod, name, external_only=True, _seen=None):
        """Is ``name`` an item of ``mod`` (directly or via re-export)?"""
        if _seen is None:
            _seen = set()
        key = (mod.path, name, external_only)
        if key in _seen:
            return False
        _seen.add(key)
        if name in mod.items:
            return True
        if not external_only and name in mod.crate_items:
            return True
        for exported, segs in mod.reexports:
            if exported == name:
                src_mod, rest = self.resolve_module(mod, segs[:-1])
                target = segs[-1]
                if not rest:
                    if target in src_mod.submodules or self.has_item(
                        src_mod, target, external_only=False, _seen=_seen
                    ):
                        return True
                # unresolvable re-export source (e.g. external crate):
                # be permissive — the rule checks our wiring, not std's
                else:
                    return True
            elif exported is None:  # glob re-export
                src_mod, rest = self.resolve_module(mod, segs[:-1])
                if not rest and self.has_item(
                    src_mod, name, external_only=False, _seen=_seen
                ):
                    return True
                if rest:  # glob from something we can't see: permissive
                    return True
        return False

    def resolve_external_path(self, segs):
        """Resolve a ``gad::…`` path as tests/benches see it. Returns
        None if OK, else a message."""
        if not segs:
            return None
        if segs[0] not in ("crate", CRATE):
            return None  # not our crate
        mod, rest = self.resolve_module(self.root, segs)
        if not rest:
            return None  # a module path — fine (use gad::obs::trace;)
        leaf = rest[0]
        if leaf == "*":
            return None
        if self.has_item(mod, leaf, external_only=True):
            return None  # anything after the item = assoc fn/variant: skip
        where = "::".join(mod.path) or "crate root"
        if len(rest) > 1:
            return (
                f"`{'::'.join(segs)}`: segment `{leaf}` is neither a module nor a "
                f"pub item of `{where}`"
            )
        return f"`{'::'.join(segs)}`: `{leaf}` is not a pub item of `{where}`"


# only `gad::…` — in a test/bench crate `crate::` means the test crate
# itself, not the library
USE_GAD = re.compile(rf"^\s*(?:pub\s+)?use\s+({CRATE}::[^;]+);", re.S | re.M)
INLINE_GAD = re.compile(rf"(?<![A-Za-z0-9_:]){CRATE}((?:::[A-Za-z_][A-Za-z0-9_]*)+)")


def run(ctx):
    findings = []
    root = build_module_tree(ctx)
    resolver = Resolver(root)
    for sf in ctx.files:
        if sf.kind not in ("test", "bench", "example"):
            continue
        stmts = _collect_statements(sf.pure)
        seen_keys = set()
        for i, text in stmts:
            m = USE_GAD.match(text)
            if m:
                for segs, _alias in split_use_tree(m.group(1)):
                    err = resolver.resolve_external_path(segs)
                    if err:
                        key = f"X-UNRESOLVED:{sf.relpath}:{'-'.join(s for s in segs if s != '*')}"
                        if key in seen_keys:
                            continue
                        seen_keys.add(key)
                        findings.append(
                            Finding(
                                rule="X-UNRESOLVED",
                                severity="error",
                                relpath=sf.relpath,
                                line=i + 1,
                                message=f"unresolved import {err}",
                                key=key,
                            )
                        )
                continue
            for m2 in INLINE_GAD.finditer(text):
                segs = [CRATE] + m2.group(1).strip(":").split("::")
                err = resolver.resolve_external_path(segs)
                if err:
                    key = f"X-UNRESOLVED:{sf.relpath}:{'-'.join(segs)}"
                    if key in seen_keys:
                        continue
                    seen_keys.add(key)
                    findings.append(
                        Finding(
                            rule="X-UNRESOLVED",
                            severity="error",
                            relpath=sf.relpath,
                            line=i + 1,
                            message=f"unresolved path {err}",
                            key=key,
                        )
                    )
    return findings
