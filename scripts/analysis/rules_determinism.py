"""Rule family D — determinism.

The repo's core claim (bit-identical answers across thread widths,
delta modes, tracing on/off, and replay) dies the moment wall-clock or
iteration-order nondeterminism leaks into an output-affecting path.
Three rules:

* ``D-TIME-BANNED`` (error, NOT allowlistable): any clock read —
  ``Instant::now`` / ``SystemTime`` / ``UNIX_EPOCH`` / ``.elapsed(`` /
  a ``std::time`` import — inside the hard-deterministic zones:
  ``rust/src/graph/``, ``rust/src/tensor/``, ``rust/src/augment/``,
  ``rust/src/loadgen/generator.rs``. These modules feed answer bits;
  PR 6 specifically evicted ``Instant`` from ``DeltaCsr`` and the
  generator's determinism contract ("never reads server state") is the
  reason same-seed replay is byte-identical.
* ``D-TIME`` (warn, allowlistable): clock reads anywhere else under
  ``rust/src/`` need an explicit allowlist entry saying *why* the read
  is wall-clock-only (bench timing, trace spans, sim service-time
  folding). Benches, tests, and examples are implicitly allowed.
* ``D-HASH-ITER`` (warn, allowlistable): iteration over a
  ``HashMap``/``HashSet``-typed binding with no sort within the
  following lines and no order-insensitive terminal on the same line.
  Heuristic by design — the allowlist records the human argument for
  every site where unordered iteration is provably harmless.
* ``D-ENTROPY`` (error, allowlistable): ambient-entropy constructs
  (``thread_rng``, ``from_entropy``, ``getrandom``, ``RandomState``,
  ``rand::``) anywhere outside ``rust/src/rng.rs``. All randomness
  flows through the seeded splitmix in ``rng.rs``.
"""

from __future__ import annotations

import re

from rustlex import Finding, make_key

BANNED_ZONES = (
    "rust/src/graph/",
    "rust/src/tensor/",
    "rust/src/augment/",
    "rust/src/loadgen/generator.rs",
)

CLOCK_TOKENS = re.compile(
    r"Instant::now\b|SystemTime\b|UNIX_EPOCH\b|\.elapsed\s*\(|std::time\b"
)
# outside banned zones only actual clock *reads* matter; importing
# Duration for arithmetic is deterministic
CLOCK_READS = re.compile(r"Instant::now\b|SystemTime::now\b|UNIX_EPOCH\b")

ENTROPY = re.compile(
    r"\bthread_rng\b|\bfrom_entropy\b|\bgetrandom\b|\bRandomState\b|\brand::"
)

HASH_ITER_METHODS = r"iter|iter_mut|keys|values|values_mut|drain|into_iter"
# terminals on the same line that cannot observe iteration order
ORDER_INSENSITIVE = re.compile(
    r"\.count\(\)|\.len\(\)|\.any\(|\.all\(|\.contains|\.min\(\)|\.max\(\)"
)
SORT_WINDOW = 4  # lines after the iteration in which a sort redeems it


def _in_banned_zone(relpath: str) -> bool:
    return any(relpath.startswith(z) for z in BANNED_ZONES)


def _hash_bindings(sf):
    """``(locals, fields)`` bound to HashMap/HashSet in this file.
    Locals (let bindings, fn params) are matched as bare receivers
    (``name.iter()``); struct fields only as prefixed receivers
    (``self.name.iter()``, ``x.name.iter()``) — a local Vec named like
    a field elsewhere must not fire the rule."""
    locals_, fields = set(), set()
    local_pats = [
        r"let\s+(?:mut\s+)?(\w+)\s*:\s*[^=;]*?\bHash(?:Map|Set)\b",
        r"let\s+(?:mut\s+)?(\w+)\s*=\s*[A-Za-z0-9_:]*\bHash(?:Map|Set)\b\s*::",
        r"(\w+)\s*:\s*&(?:mut\s+)?[A-Za-z0-9_:]*\bHash(?:Map|Set)\s*<",
    ]
    field_pat = r"^\s*(?:pub(?:\([^)]*\))?\s+)?(\w+)\s*:\s*[^,;=]*?\bHash(?:Map|Set)\s*<"
    for line in sf.pure:
        for p in local_pats:
            for m in re.finditer(p, line):
                locals_.add(m.group(1))
        m = re.match(field_pat, line)
        if m:
            fields.add(m.group(1))
    locals_.discard("self")
    fields.discard("self")
    return locals_, fields


def run(ctx):
    findings = []
    for sf in ctx.files:
        if sf.kind == "src":
            findings.extend(_check_time_src(sf))
            findings.extend(_check_entropy(sf))
        # hash-iteration order matters wherever output is produced;
        # tests/benches assert on output too, but their authors see the
        # flake immediately — keep the rule to library code.
        if sf.kind == "src":
            findings.extend(_check_hash_iter(sf))
    return findings


def _check_time_src(sf):
    out = []
    banned = _in_banned_zone(sf.relpath)
    pat = CLOCK_TOKENS if banned else CLOCK_READS
    for i, line in enumerate(sf.pure):
        if sf.in_test(i):
            continue
        if pat.search(line):
            if banned:
                out.append(
                    Finding(
                        rule="D-TIME-BANNED",
                        severity="error",
                        relpath=sf.relpath,
                        line=i + 1,
                        message=(
                            "clock/time construct in a hard-deterministic zone "
                            "(graph/, tensor/, augment/, loadgen/generator.rs): "
                            f"`{sf.raw[i].strip()[:80]}` — these modules feed answer "
                            "bits; no allowlist exemption exists for this rule"
                        ),
                        key=make_key("D-TIME-BANNED", sf.relpath, sf.raw[i]),
                        suppressable=False,
                    )
                )
            else:
                out.append(
                    Finding(
                        rule="D-TIME",
                        severity="warn",
                        relpath=sf.relpath,
                        line=i + 1,
                        message=(
                            f"wall-clock read in library code: `{sf.raw[i].strip()[:80]}` "
                            "— needs an allowlist entry naming why this is "
                            "wall-clock-only (never feeds answers/counters/replay)"
                        ),
                        key=make_key("D-TIME", sf.relpath, sf.raw[i]),
                    )
                )
    return out


def _check_entropy(sf):
    out = []
    if sf.relpath == "rust/src/rng.rs":
        return out
    for i, line in enumerate(sf.pure):
        if sf.in_test(i):
            continue
        if ENTROPY.search(line):
            out.append(
                Finding(
                    rule="D-ENTROPY",
                    severity="error",
                    relpath=sf.relpath,
                    line=i + 1,
                    message=(
                        f"ambient entropy outside rng.rs: `{sf.raw[i].strip()[:80]}` "
                        "— all randomness must flow through the seeded rng::Rng"
                    ),
                    key=make_key("D-ENTROPY", sf.relpath, sf.raw[i]),
                )
            )
    return out


def _check_hash_iter(sf):
    out = []
    locals_, fields = _hash_bindings(sf)
    if not locals_ and not fields:
        return out
    pats = []
    if locals_:
        alt = "|".join(sorted(re.escape(n) for n in locals_))
        pats.append(
            re.compile(rf"(?:^|[^\w.])({alt})\s*\.\s*({HASH_ITER_METHODS})\s*\(")
        )
        pats.append(
            re.compile(rf"\bfor\s+[^;{{]*?\bin\s+&?(?:mut\s+)?({alt})\b[^.\w]")
        )
    if fields:
        alt = "|".join(sorted(re.escape(n) for n in fields))
        pats.append(
            re.compile(rf"[\w\])]\s*\.\s*({alt})\s*\.\s*({HASH_ITER_METHODS})\s*\(")
        )
        pats.append(
            re.compile(rf"\bfor\s+[^;{{]*?\bin\s+&?(?:mut\s+)?[\w.]+\.({alt})\b[^.\w]")
        )
    for i, line in enumerate(sf.pure):
        if sf.in_test(i):
            continue
        m = None
        for p in pats:
            m = p.search(line + " ")
            if m:
                break
        if not m:
            continue
        if ORDER_INSENSITIVE.search(line):
            continue
        window = " ".join(sf.pure[i : i + SORT_WINDOW])
        if "sort" in window or "BTree" in window:
            continue
        out.append(
            Finding(
                rule="D-HASH-ITER",
                severity="warn",
                relpath=sf.relpath,
                line=i + 1,
                message=(
                    f"iteration over hash collection `{m.group(1)}` with no sort in "
                    f"the next {SORT_WINDOW} lines: `{sf.raw[i].strip()[:80]}` — sort "
                    "the keys, collect into a BTree, or allowlist with the argument "
                    "for why order cannot reach any output"
                ),
                key=make_key("D-HASH-ITER", sf.relpath, sf.raw[i]),
            )
        )
    return out
