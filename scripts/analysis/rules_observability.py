"""Rule family O — observability.

The tracer (``rust/src/obs/``) only earns its keep if the span
inventory in README stays true, cross-thread parent links are captured
on the right side of the fork, and every retained ``*_reference``
oracle still has a live optimized twin with a test pinning the pair.

* ``O-SPAN-INVENTORY`` (error): a span name emitted by ``span!`` /
  ``virtual_span`` / ``SpanGuard::enter[_under]`` in ``rust/src/`` that
  README's span-inventory block (between ``<!-- span-inventory:begin
  -->`` and ``<!-- span-inventory:end -->``) does not list.
* ``O-SPAN-STALE`` (error): the reverse — README lists a span no code
  emits. Docs that describe spans that no longer exist are worse than
  no docs.
* ``O-ENTER-UNDER`` (error): ``SpanGuard::enter_under(.., Some(x), ..)``
  inside a ``std::thread::scope`` block where ``x`` was not assigned
  before the scope opened. The parent span id must be captured on the
  dispatching thread *before* the fork, or the workers race the
  thread-local stack they were supposed to bypass.
* ``O-REFERENCE-TWIN`` (error): a ``pub fn *_reference`` oracle whose
  optimized twin (name with ``_reference`` removed) is missing, or
  with no single test/bench file referencing both names — the
  bit-identity property the oracle exists for is then untested.
"""

from __future__ import annotations

import re

from rustlex import Finding, make_key

SPAN_NAME = re.compile(
    r"(?:\bspan!\s*\(|\bvirtual_span\s*\(|SpanGuard::enter(?:_under)?\s*\()\s*\n?\s*\"([^\"]+)\"",
    re.S,
)
INVENTORY_BEGIN = "<!-- span-inventory:begin -->"
INVENTORY_END = "<!-- span-inventory:end -->"
ENTER_UNDER = re.compile(r"SpanGuard::enter_under\s*\(")
REFERENCE_FN = re.compile(r"\bpub\s+fn\s+(\w*_reference\w*)\s*\(")


def run(ctx):
    findings = []
    findings.extend(_check_inventory(ctx))
    findings.extend(_check_enter_under(ctx))
    findings.extend(_check_reference_twins(ctx))
    return findings


def _emitted_spans(ctx):
    """name -> (relpath, 1-based line) of one emission site."""
    spans = {}
    for sf in ctx.files:
        if sf.kind != "src":
            continue
        text = sf.code_text()
        for m in SPAN_NAME.finditer(text):
            line = text.count("\n", 0, m.start()) + 1
            if sf.in_test(line - 1):
                continue
            spans.setdefault(m.group(1), (sf.relpath, line))
    return spans


def _inventory_spans(ctx):
    """Backticked tier.phase tokens inside the README inventory block."""
    text = ctx.readme_text
    lo = text.find(INVENTORY_BEGIN)
    hi = text.find(INVENTORY_END)
    if lo < 0 or hi < 0 or hi < lo:
        return None
    block = text[lo:hi]
    return set(re.findall(r"`(\w+\.\w+)`", block))


def _check_inventory(ctx):
    out = []
    emitted = _emitted_spans(ctx)
    listed = _inventory_spans(ctx)
    if listed is None:
        out.append(
            Finding(
                rule="O-SPAN-INVENTORY",
                severity="error",
                relpath="README.md",
                line=0,
                message=(
                    "README has no span-inventory block (markers "
                    f"`{INVENTORY_BEGIN}` … `{INVENTORY_END}`) — the span "
                    "inventory cross-check cannot run"
                ),
                key="O-SPAN-INVENTORY:README.md:missing-block",
                suppressable=False,
            )
        )
        return out
    for name, (relpath, line) in sorted(emitted.items()):
        if name not in listed:
            out.append(
                Finding(
                    rule="O-SPAN-INVENTORY",
                    severity="error",
                    relpath=relpath,
                    line=line,
                    message=(
                        f"span `{name}` is emitted here but missing from README's "
                        "span inventory — document it (name, clock, where)"
                    ),
                    key=f"O-SPAN-INVENTORY:{relpath}:{name}",
                    suppressable=False,
                )
            )
    for name in sorted(listed - set(emitted)):
        out.append(
            Finding(
                rule="O-SPAN-STALE",
                severity="error",
                relpath="README.md",
                line=0,
                message=(
                    f"README's span inventory lists `{name}` but no code in "
                    "rust/src emits it — remove the stale row"
                ),
                key=f"O-SPAN-STALE:README.md:{name}",
                suppressable=False,
            )
        )
    return out


def _check_enter_under(ctx):
    out = []
    for sf in ctx.files:
        if sf.kind != "src":
            continue
        scope_lines = [
            i for i, l in enumerate(sf.pure) if re.search(r"thread::scope\s*\(", l)
        ]
        if not scope_lines:
            continue
        text = sf.code_text()
        for m in ENTER_UNDER.finditer(text):
            line0 = text.count("\n", 0, m.start())  # 0-based
            if sf.in_test(line0):
                continue
            # nearest scope opening at or before this call = the fork
            # this call runs inside (enter_under before any scope is
            # same-thread use and needs no capture discipline)
            encl = [s for s in scope_lines if s <= line0]
            if not encl:
                continue
            scope_line = encl[-1]
            # the parent argument: Some(ident) within the call's args
            tail = text[m.end() : m.end() + 200]
            pm = re.search(r"Some\s*\(\s*(\w+)\s*\)", tail)
            if not pm:
                continue  # None / computed parent: nothing to cross-check
            ident = pm.group(1)
            assigned_before = any(
                re.search(rf"\blet\s+(?:mut\s+)?{re.escape(ident)}\b", sf.pure[j])
                or re.search(rf"\b{re.escape(ident)}\s*=[^=]", sf.pure[j])
                for j in range(0, scope_line)
            )
            if not assigned_before:
                out.append(
                    Finding(
                        rule="O-ENTER-UNDER",
                        severity="error",
                        relpath=sf.relpath,
                        line=line0 + 1,
                        message=(
                            f"enter_under parent `{ident}` is not assigned before "
                            f"the enclosing thread::scope (line {scope_line + 1}) — "
                            "capture the span id on the dispatching thread before "
                            "the fork"
                        ),
                        key=f"O-ENTER-UNDER:{sf.relpath}:{ident}",
                    )
                )
    return out


def _check_reference_twins(ctx):
    out = []
    # all *_reference oracles declared in src
    oracles = []  # (name, relpath, line)
    src_text_all = []
    for sf in ctx.files:
        if sf.kind == "src":
            src_text_all.append(sf.pure_text())
            for i, line in enumerate(sf.pure):
                m = REFERENCE_FN.search(line)
                if m and not sf.in_test(i):
                    oracles.append((m.group(1), sf.relpath, i + 1))
    src_blob = "\n".join(src_text_all)
    # files that may carry the pinning test: integration/prop tests,
    # benches, and #[cfg(test)] regions inside src
    test_files = []
    for sf in ctx.files:
        if sf.kind in ("test", "bench"):
            test_files.append(sf.pure_text())
        elif sf.kind == "src":
            tl = [l for i, l in enumerate(sf.pure) if sf.in_test(i)]
            if tl:
                test_files.append("\n".join(tl))
    for name, relpath, line in oracles:
        twin = name.replace("_reference", "", 1)
        if not re.search(rf"\bfn\s+{re.escape(twin)}\s*\(", src_blob):
            out.append(
                Finding(
                    rule="O-REFERENCE-TWIN",
                    severity="error",
                    relpath=relpath,
                    line=line,
                    message=(
                        f"oracle `{name}` has no optimized twin `{twin}` anywhere "
                        "in rust/src — a reference with nothing to check is dead "
                        "weight; delete it or restore the twin"
                    ),
                    key=f"O-REFERENCE-TWIN:{relpath}:{name}",
                )
            )
            continue
        pinned = any(
            re.search(rf"\b{re.escape(name)}\b", t)
            and re.search(rf"\b{re.escape(twin)}\b(?!_)", t)
            for t in test_files
        )
        if not pinned:
            out.append(
                Finding(
                    rule="O-REFERENCE-TWIN",
                    severity="error",
                    relpath=relpath,
                    line=line,
                    message=(
                        f"no single test/bench file references both `{name}` and "
                        f"`{twin}` — the bit-identity pair is unpinned"
                    ),
                    key=f"O-REFERENCE-TWIN:{relpath}:{name}",
                )
            )
    return out
