"""L1 — blocked Pallas kernels for the GCN layer hot spot.

Computes ``Z = A @ (X @ W)`` (optionally ReLU'd) — the paper's
per-processor hot spot (Eq. 7) — rethought for TPU:

* ``X @ W`` feeds the MXU as (BM, BK) x (BK, BN) f32 tiles;
* the neighbourhood aggregation ``A @ (XW)`` — a warp-level sparse
  gather on the paper's GPUs — becomes a second blocked dense matmul
  over the padded normalized adjacency. For the <= 2k-node subgraphs
  GAD-Partition produces this is the right trade on a systolic array
  (see DESIGN.md §Hardware-Adaptation);
* the grid walks (i, j, k) with k innermost; the output tile is
  revisited across the k sweep and used as the accumulator, so each
  (i, j) tile stays resident in VMEM while A/X tiles stream from HBM —
  the BlockSpec index maps express the HBM<->VMEM schedule the paper's
  CUDA code expressed with threadblocks, and the pallas pipeline
  double-buffers the streamed tiles.

VMEM budget per grid step: 3 tiles x 128x128 x 4 B = 192 KiB, far
under the ~16 MB budget; see EXPERIMENTS.md §Perf for the MXU
utilisation estimate.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU perf is *estimated*, not measured.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: multiples of the MXU's 128x128 systolic array.
BM = 128
BN = 128
BK = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_tiles: int, activate: bool):
    """Blocked ``o = x @ w``; the output tile accumulates across the
    innermost k sweep, ReLU applied on the final k step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    if activate:

        @pl.when(k == k_tiles - 1)
        def _relu():
            o_ref[...] = jnp.maximum(o_ref[...], 0.0)


def matmul_pallas(x, w, *, activate: bool = False, interpret: bool = True):
    """Blocked Pallas matmul; pads operands to tile multiples and crops
    the result, so any f32 shape works."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    mp, kp, np_ = _ceil_to(m, BM), _ceil_to(k, BK), _ceil_to(n, BN)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    k_tiles = kp // BK
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_tiles=k_tiles, activate=activate),
        grid=(mp // BM, np_ // BN, k_tiles),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def gcn_layer_pallas(adj, x, w, *, activate: bool = False, interpret: bool = True):
    """One GCN layer ``Z = adj @ (x @ w)``.

    ``X @ W`` runs first: with X (n, f) and W (f, h), XW (n, h) is the
    cheap intermediate (h << f for the input layer); aggregating first
    would put the wide f-dimension through the second matmul too.
    """
    xw = matmul_pallas(x, w, interpret=interpret)
    return matmul_pallas(adj, xw, activate=activate, interpret=interpret)
