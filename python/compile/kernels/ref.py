"""Pure-jnp oracles for every Pallas kernel and for the full GCN model.

These are the CORE correctness baseline: pytest asserts the Pallas
kernels (interpret mode) and the AOT-lowered HLO agree with these
functions to float32 tolerance.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain dense matmul."""
    return jnp.matmul(x, w)


def gcn_layer_ref(adj, x, w, *, activate: bool):
    """One GCN layer: Z = adj @ (x @ w), optional ReLU (paper Eq. 7).

    `adj` is the symmetric-normalized dense adjacency (with self loops)
    of the padded subgraph; `x` the node features/embeddings.
    """
    z = jnp.matmul(adj, jnp.matmul(x, w))
    return jnp.maximum(z, 0.0) if activate else z


def gcn_forward_ref(adj, x, ws):
    """L-layer GCN forward producing logits (paper Eq. 8, pre-softmax)."""
    h = x
    for i, w in enumerate(ws):
        h = gcn_layer_ref(adj, h, w, activate=i + 1 < len(ws))
    return h


def masked_ce_loss_ref(logits, y_onehot, mask):
    """Masked mean softmax cross-entropy (paper Eq. 9, softmax form).

    `mask` is float {0,1} per node; padded rows carry mask 0.
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    per_node = -jnp.sum(y_onehot * logp, axis=-1)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_node * mask) / denom


# jax import placed late so ref stays importable in docs tooling
import jax  # noqa: E402
