"""L1 Pallas kernels (build-time only; never imported at runtime)."""

from .gcn_layer import gcn_layer_pallas, matmul_pallas, BM, BN, BK  # noqa: F401
