"""L1 — masked softmax cross-entropy as a blocked Pallas kernel.

The loss layer (paper Eq. 9, softmax form) as row-blocked kernels:

* forward: per-node `-(y · log_softmax(z))` over (BM, C) tiles — one
  VMEM-resident row block per grid step, the row reduction runs on the
  VPU lanes;
* backward: `(softmax(z) - y) * mask / denom` with the same tiling.

Both directions are Pallas, glued by a ``custom_vjp`` in
`masked_ce_pallas`, so the AOT train artifact's loss layer also lowers
from L1 kernels. interpret=True as everywhere (CPU PJRT).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block height; class dim is kept whole (c <= a few hundred).
BM = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _ce_fwd_kernel(z_ref, y_ref, o_ref):
    """Per-row CE: o[i] = -sum_c y[i,c] * log_softmax(z)[i,c]."""
    z = z_ref[...]
    m = jnp.max(z, axis=-1, keepdims=True)
    shifted = z - m
    logsumexp = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))
    logp = shifted - logsumexp
    o_ref[...] = -jnp.sum(y_ref[...] * logp, axis=-1)


def _ce_bwd_kernel(z_ref, y_ref, s_ref, o_ref):
    """dL/dz rows: (softmax(z) - y) * s  (s = mask/denom scale)."""
    z = z_ref[...]
    m = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (p - y_ref[...]) * s_ref[...][:, None]


def _rows_call(kernel, out_shape_cols, logits, *args, interpret=True):
    """Run a row-blocked kernel over padded (n, c) inputs."""
    n, c = logits.shape
    npad = _ceil_to(n, BM)
    padded = [jnp.pad(a, ((0, npad - n),) + ((0, 0),) * (a.ndim - 1)) for a in (logits, *args)]
    if out_shape_cols == 0:
        out_shape = jax.ShapeDtypeStruct((npad,), jnp.float32)
        out_spec = pl.BlockSpec((BM,), lambda i: (i,))
    else:
        out_shape = jax.ShapeDtypeStruct((npad, c), jnp.float32)
        out_spec = pl.BlockSpec((BM, c), lambda i: (i, 0))
    in_specs = []
    for a in padded:
        if a.ndim == 1:
            in_specs.append(pl.BlockSpec((BM,), lambda i: (i,)))
        else:
            in_specs.append(pl.BlockSpec((BM, a.shape[1]), lambda i: (i, 0)))
    out = pl.pallas_call(
        kernel,
        grid=(npad // BM,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*padded)
    return out[:n] if out_shape_cols == 0 else out[:n, :]


@jax.custom_vjp
def masked_ce_pallas(logits, y_onehot, mask):
    """Masked mean softmax CE with Pallas forward and backward."""
    per_node = _rows_call(_ce_fwd_kernel, 0, logits, y_onehot)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_node * mask) / denom


def _fwd(logits, y_onehot, mask):
    return masked_ce_pallas(logits, y_onehot, mask), (logits, y_onehot, mask)


def _bwd(res, g):
    logits, y_onehot, mask = res
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    scale = g * mask / denom
    dlogits = _rows_call(_ce_bwd_kernel, logits.shape[1], logits, y_onehot, scale)
    # labels / mask are constants of the training problem
    return dlogits, jnp.zeros_like(y_onehot), jnp.zeros_like(mask)


masked_ce_pallas.defvjp(_fwd, _bwd)
