"""L2 — the GCN model as a JAX program over the L1 Pallas kernels.

The Pallas matmul is wrapped in a ``custom_vjp`` whose backward is
*also* expressed with the Pallas kernel, so the whole train step —
forward, loss and gradients — lowers into one HLO module built from the
L1 kernels. ``aot.py`` lowers `train_step` / `predict` per shape bucket
and the rust runtime executes them via PJRT.
"""

import jax
import jax.numpy as jnp

from .kernels.gcn_layer import matmul_pallas


# ---------------------------------------------------------------------
# differentiable pallas matmul
# ---------------------------------------------------------------------

@jax.custom_vjp
def pmm(x, w):
    """Pallas matmul with a Pallas backward."""
    return matmul_pallas(x, w)


def _pmm_fwd(x, w):
    return matmul_pallas(x, w), (x, w)


def _pmm_bwd(res, g):
    x, w = res
    # dX = g W^T, dW = X^T g — both through the same blocked kernel
    dx = matmul_pallas(g, w.T)
    dw = matmul_pallas(x.T, g)
    return dx, dw


pmm.defvjp(_pmm_fwd, _pmm_bwd)


# ---------------------------------------------------------------------
# model
# ---------------------------------------------------------------------

def gcn_logits(adj, x, ws):
    """L-layer GCN (paper Eq. 7/8, pre-softmax): hidden layers ReLU'd,
    aggregation and feature transform through the Pallas kernel."""
    h = x
    last = len(ws) - 1
    for i, w in enumerate(ws):
        h = pmm(adj, pmm(h, w))
        if i != last:
            h = jnp.maximum(h, 0.0)
    return h


def masked_ce_loss(logits, y_onehot, mask):
    """Masked mean softmax cross-entropy (Eq. 9, softmax form), via the
    L1 Pallas kernel (forward AND backward lower from Pallas).
    Padded rows carry ``mask == 0`` and contribute nothing."""
    from .kernels.softmax_ce import masked_ce_pallas

    return masked_ce_pallas(logits, y_onehot, mask)


def masked_ce_loss_jnp(logits, y_onehot, mask):
    """Pure-jnp loss (cross-check oracle for the Pallas kernel)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    per_node = -jnp.sum(y_onehot * logp, axis=-1)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_node * mask) / denom


def make_train_step(num_layers):
    """`(adj, x, y, mask, *ws) -> (loss, *grads)` for the AOT bucket."""

    def train_step(adj, x, y_onehot, mask, *ws):
        def loss_of(ws_tuple):
            return masked_ce_loss(gcn_logits(adj, x, ws_tuple), y_onehot, mask)

        loss, grads = jax.value_and_grad(loss_of)(tuple(ws))
        assert len(grads) == num_layers
        return (loss, *grads)

    return train_step


def make_predict(num_layers):  # noqa: ARG001 — symmetry with train
    """`(adj, x, *ws) -> (logits,)` for the AOT bucket."""

    def predict(adj, x, *ws):
        return (gcn_logits(adj, x, ws),)

    return predict


def weight_shapes(layers, fdim, hidden, classes):
    """Weight matrix shapes `f -> h -> ... -> h -> c` (mirrors
    rust/src/model/params.rs)."""
    if layers == 1:
        return [(fdim, classes)]
    shapes = [(fdim, hidden)]
    shapes += [(hidden, hidden)] * (layers - 2)
    shapes.append((hidden, classes))
    return shapes
