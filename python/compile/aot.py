"""AOT lowering: L2 model (wrapping the L1 Pallas kernels) -> HLO text.

Emits HLO **text**, not a serialized ``HloModuleProto``: jax >= 0.5
writes protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and rust/src/runtime/).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts
    # extra shape buckets:
    python -m compile.aot --out-dir ../artifacts \
        --variant 2,512,500,256,3

Writes ``<out>/manifest.txt`` with one line per artifact:
``kind layers nodes fdim hidden classes file`` — parsed by
rust/src/runtime/manifest.rs.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import make_predict, make_train_step, weight_shapes

# Default buckets: (layers, nodes, fdim, hidden, classes).
#   f32/c4/h32  — the `tiny` dataset (tests + quickstart example)
#   f1433/c7/h128 — cora-scale (end_to_end_train example)
DEFAULT_VARIANTS = [
    (2, 128, 32, 32, 4),
    (2, 256, 32, 32, 4),
    (2, 512, 32, 32, 4),
    (2, 256, 1433, 128, 7),
    (2, 512, 1433, 128, 7),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unpacks a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(layers, nodes, fdim, hidden, classes):
    """Lower (train, predict) for one shape bucket; returns dict of
    kind -> hlo text."""
    f32 = jax.numpy.float32
    spec = jax.ShapeDtypeStruct
    adj = spec((nodes, nodes), f32)
    x = spec((nodes, fdim), f32)
    y = spec((nodes, classes), f32)
    mask = spec((nodes,), f32)
    ws = [spec(s, f32) for s in weight_shapes(layers, fdim, hidden, classes)]

    train = jax.jit(make_train_step(layers)).lower(adj, x, y, mask, *ws)
    predict = jax.jit(make_predict(layers)).lower(adj, x, *ws)
    return {"train": to_hlo_text(train), "predict": to_hlo_text(predict)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variant",
        action="append",
        default=[],
        metavar="L,N,F,H,C",
        help="extra bucket: layers,nodes,fdim,hidden,classes",
    )
    ap.add_argument("--no-defaults", action="store_true", help="skip DEFAULT_VARIANTS")
    args = ap.parse_args()

    variants = [] if args.no_defaults else list(DEFAULT_VARIANTS)
    for v in args.variant:
        parts = tuple(int(p) for p in v.split(","))
        assert len(parts) == 5, f"bad --variant '{v}'"
        variants.append(parts)

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = ["# kind layers nodes fdim hidden classes file"]
    for layers, nodes, fdim, hidden, classes in variants:
        hlos = lower_variant(layers, nodes, fdim, hidden, classes)
        for kind, text in hlos.items():
            fname = f"{kind}_l{layers}_n{nodes}_f{fdim}_h{hidden}_c{classes}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            manifest_lines.append(
                f"{kind} {layers} {nodes} {fdim} {hidden} {classes} {fname}"
            )
            print(f"wrote {fname} ({len(text) / 1e6:.2f} MB)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(manifest_lines) - 1} artifacts")


if __name__ == "__main__":
    main()
