"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

The hypothesis sweeps are the CORE correctness signal for the kernel:
random shapes (aligned and ragged vs the 128-tile), random values.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gcn_layer import gcn_layer_pallas, matmul_pallas
from compile.kernels.ref import gcn_layer_ref, matmul_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


class TestMatmulPallas:
    @pytest.mark.parametrize(
        "m,k,n",
        [
            (1, 1, 1),
            (7, 5, 3),
            (128, 128, 128),  # exactly one tile
            (128, 256, 128),  # multi-tile k sweep
            (130, 129, 131),  # ragged: forces padding + crop
            (200, 64, 300),
        ],
    )
    def test_matches_ref(self, m, k, n):
        x, w = rand(m * 1000 + k, m, k), rand(n, k, n)
        got = matmul_pallas(x, w)
        np.testing.assert_allclose(got, matmul_ref(x, w), rtol=1e-4, atol=1e-4)

    def test_relu_fusion(self):
        x, w = rand(1, 64, 32), rand(2, 32, 16)
        got = matmul_pallas(x, w, activate=True)
        want = jnp.maximum(matmul_ref(x, w), 0.0)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        assert (np.asarray(got) >= 0).all()

    def test_zero_inputs(self):
        x = jnp.zeros((16, 8), jnp.float32)
        w = jnp.zeros((8, 4), jnp.float32)
        np.testing.assert_array_equal(matmul_pallas(x, w), jnp.zeros((16, 4)))

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 160),
        k=st.integers(1, 160),
        n=st.integers(1, 160),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, m, k, n, seed):
        x, w = rand(seed, m, k), rand(seed + 1, k, n)
        got = matmul_pallas(x, w)
        np.testing.assert_allclose(got, matmul_ref(x, w), rtol=2e-4, atol=2e-4)


class TestGcnLayerPallas:
    @pytest.mark.parametrize("n,f,h", [(8, 16, 4), (64, 32, 8), (130, 40, 12)])
    @pytest.mark.parametrize("activate", [False, True])
    def test_matches_ref(self, n, f, h, activate):
        adj = rand(n, n, n)
        x = rand(f, n, f)
        w = rand(h, f, h)
        got = gcn_layer_pallas(adj, x, w, activate=activate)
        want = gcn_layer_ref(adj, x, w, activate=activate)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_identity_adjacency_reduces_to_matmul(self):
        n, f, h = 24, 12, 6
        adj = jnp.eye(n, dtype=jnp.float32)
        x, w = rand(1, n, f), rand(2, f, h)
        got = gcn_layer_pallas(adj, x, w, activate=False)
        np.testing.assert_allclose(got, matmul_ref(x, w), rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(2, 96), f=st.integers(1, 96), h=st.integers(1, 48), seed=st.integers(0, 10**6))
    def test_hypothesis_layer_sweep(self, n, f, h, seed):
        adj, x, w = rand(seed, n, n), rand(seed + 1, n, f), rand(seed + 2, f, h)
        got = gcn_layer_pallas(adj, x, w, activate=True)
        want = gcn_layer_ref(adj, x, w, activate=True)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)
