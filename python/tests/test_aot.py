"""AOT path: lowering produces parseable HLO text with the agreed
input/output arity, and the numbers coming out of the XLA computation
match the reference model."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import lower_variant, to_hlo_text
from compile.kernels.ref import gcn_forward_ref
from compile.model import make_predict, weight_shapes

jax.config.update("jax_platform_name", "cpu")


def test_lower_variant_emits_both_kinds():
    hlos = lower_variant(2, 64, 16, 8, 4)
    assert set(hlos) == {"train", "predict"}
    for text in hlos.values():
        assert "ENTRY" in text, "expected HLO text with ENTRY"
        assert len(text) > 1000


def test_hlo_mentions_tuple_root():
    hlos = lower_variant(1, 32, 8, 0, 3)
    # return_tuple=True -> root instruction produces a tuple
    assert "tuple" in hlos["predict"].lower()


def test_roundtrip_numerics_via_xla_client():
    """Compile the lowered predict HLO with the *local* xla client and
    compare against the jnp reference — the same check the rust side
    repeats through PJRT (rust/tests/integration_runtime.rs)."""
    n, f, h, c, layers = 32, 8, 8, 3, 2
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    adj = jnp.asarray(jax.random.uniform(ks[0], (n, n)) < 0.1, jnp.float32)
    x = jax.random.normal(ks[1], (n, f))
    ws = [
        0.5 * jax.random.normal(ks[2 + i], s)
        for i, s in enumerate(weight_shapes(layers, f, h, c))
    ]
    predict = jax.jit(make_predict(layers))
    (got,) = predict(adj, x, *ws)
    want = gcn_forward_ref(adj, x, ws)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_manifest_written(tmp_path):
    """End-to-end aot.py main() with one tiny variant."""
    import subprocess
    import sys

    out = tmp_path / "arts"
    res = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--no-defaults",
            "--variant",
            "1,32,8,0,3",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert res.returncode == 0, res.stderr
    manifest = (out / "manifest.txt").read_text()
    lines = [l for l in manifest.splitlines() if l and not l.startswith("#")]
    assert len(lines) == 2  # train + predict
    for line in lines:
        fields = line.split()
        assert len(fields) == 7
        assert (out / fields[6]).exists()
