"""L1 correctness: the Pallas masked softmax-CE kernel vs jnp."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import masked_ce_loss_ref
from compile.kernels.softmax_ce import masked_ce_pallas

jax.config.update("jax_platform_name", "cpu")


def setup(n, c, seed=0, mask_p=0.7):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    logits = 3.0 * jax.random.normal(ks[0], (n, c))
    labels = jax.random.randint(ks[1], (n,), 0, c)
    y = jax.nn.one_hot(labels, c)
    mask = jnp.asarray(jax.random.uniform(ks[2], (n,)) < mask_p, jnp.float32)
    return logits, y, mask


@pytest.mark.parametrize("n,c", [(4, 3), (128, 7), (130, 41), (300, 2)])
def test_forward_matches_ref(n, c):
    logits, y, mask = setup(n, c)
    got = masked_ce_pallas(logits, y, mask)
    want = masked_ce_loss_ref(logits, y, mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_all_masked_out_is_zero():
    logits, y, _ = setup(16, 4)
    zero = masked_ce_pallas(logits, y, jnp.zeros(16))
    assert float(zero) == 0.0


def test_gradient_matches_jnp_autodiff():
    logits, y, mask = setup(100, 7, seed=3)

    def ref_loss(z):
        return masked_ce_loss_ref(z, y, mask)

    def pallas_loss(z):
        return masked_ce_pallas(z, y, mask)

    g_ref = jax.grad(ref_loss)(logits)
    g_pal = jax.grad(pallas_loss)(logits)
    np.testing.assert_allclose(g_pal, g_ref, rtol=1e-4, atol=1e-6)


def test_masked_rows_get_zero_gradient():
    logits, y, mask = setup(64, 5, seed=5, mask_p=0.5)
    g = jax.grad(lambda z: masked_ce_pallas(z, y, mask))(logits)
    g = np.asarray(g)
    for i, m in enumerate(np.asarray(mask)):
        if m == 0.0:
            assert np.all(g[i] == 0.0), f"row {i} should be zero"


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 200), c=st.integers(2, 50), seed=st.integers(0, 10**6))
def test_hypothesis_sweep(n, c, seed):
    logits, y, mask = setup(n, c, seed=seed)
    got = masked_ce_pallas(logits, y, mask)
    want = masked_ce_loss_ref(logits, y, mask)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)
