"""L2 correctness: the custom_vjp GCN vs pure-jnp autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import gcn_forward_ref, masked_ce_loss_ref
from compile.model import (
    gcn_logits,
    make_predict,
    make_train_step,
    masked_ce_loss,
    weight_shapes,
)

jax.config.update("jax_platform_name", "cpu")


def setup(n=20, f=12, h=8, c=3, layers=2, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 8)
    # symmetric normalized-ish adjacency
    a = jax.random.uniform(keys[0], (n, n)) < 0.2
    a = jnp.asarray(a | a.T | jnp.eye(n, dtype=bool), jnp.float32)
    deg = jnp.sum(a, axis=1)
    dinv = 1.0 / jnp.sqrt(deg)
    adj = a * dinv[:, None] * dinv[None, :]
    x = jax.random.normal(keys[1], (n, f))
    labels = jax.random.randint(keys[2], (n,), 0, c)
    y = jax.nn.one_hot(labels, c)
    mask = jnp.asarray(jax.random.uniform(keys[3], (n,)) < 0.7, jnp.float32)
    ws = [
        0.3 * jax.random.normal(keys[4 + i], s)
        for i, s in enumerate(weight_shapes(layers, f, h, c))
    ]
    return adj, x, y, mask, ws


@pytest.mark.parametrize("layers", [1, 2, 3])
def test_logits_match_ref(layers):
    adj, x, _, _, ws = setup(layers=layers)
    got = gcn_logits(adj, x, ws)
    want = gcn_forward_ref(adj, x, ws)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_loss_matches_ref():
    adj, x, y, mask, ws = setup()
    got = masked_ce_loss(gcn_logits(adj, x, ws), y, mask)
    want = masked_ce_loss_ref(gcn_forward_ref(adj, x, ws), y, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("layers", [1, 2, 3])
def test_custom_vjp_grads_match_jnp_autodiff(layers):
    """The pallas-backed custom_vjp backward must equal autodiff
    through the pure-jnp reference model."""
    adj, x, y, mask, ws = setup(layers=layers)

    def loss_pallas(ws_t):
        return masked_ce_loss(gcn_logits(adj, x, list(ws_t)), y, mask)

    def loss_ref(ws_t):
        return masked_ce_loss_ref(gcn_forward_ref(adj, x, list(ws_t)), y, mask)

    g_pallas = jax.grad(loss_pallas)(tuple(ws))
    g_ref = jax.grad(loss_ref)(tuple(ws))
    for gp, gr in zip(g_pallas, g_ref):
        np.testing.assert_allclose(gp, gr, rtol=3e-4, atol=3e-4)


def test_train_step_outputs():
    adj, x, y, mask, ws = setup(layers=2)
    out = make_train_step(2)(adj, x, y, mask, *ws)
    assert len(out) == 3  # loss + 2 grads
    loss = out[0]
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    for g, w in zip(out[1:], ws):
        assert g.shape == w.shape


def test_predict_shape():
    adj, x, y, _, ws = setup(layers=2)
    (logits,) = make_predict(2)(adj, x, *ws)
    assert logits.shape == (x.shape[0], y.shape[1])


def test_padding_rows_do_not_change_loss():
    """Zero-padded rows with mask 0 must leave loss/grads unchanged —
    the invariant the rust XlaBackend's bucket padding relies on."""
    adj, x, y, mask, ws = setup(n=16)
    pad = 8
    adj_p = jnp.pad(adj, ((0, pad), (0, pad)))
    x_p = jnp.pad(x, ((0, pad), (0, 0)))
    y_p = jnp.pad(y, ((0, pad), (0, 0)))
    mask_p = jnp.pad(mask, (0, pad))

    step = make_train_step(2)
    out = step(adj, x, y, mask, *ws)
    out_p = step(adj_p, x_p, y_p, mask_p, *ws)
    np.testing.assert_allclose(out[0], out_p[0], rtol=1e-5, atol=1e-6)
    for g, gp in zip(out[1:], out_p[1:]):
        np.testing.assert_allclose(g, gp, rtol=1e-4, atol=1e-5)


def test_weight_shapes_chain():
    assert weight_shapes(1, 10, 8, 3) == [(10, 3)]
    assert weight_shapes(3, 10, 8, 3) == [(10, 8), (8, 8), (8, 3)]
